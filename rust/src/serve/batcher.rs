//! Cross-tenant micro-batching.
//!
//! The Skip-LoRA serving identity (Eq. 17): for every tenant t,
//!
//! ```text
//! logits_t(x) = c^n(x) + Σ_k adapter_{t,k}(x^k)
//! ```
//!
//! where `c^n` and the activations `x^k` depend ONLY on the shared frozen
//! backbone — not on the tenant. So B requests from B different tenants
//! cost ONE backbone forward (the expensive dense part, computed batched)
//! plus B rank-r adapter heads (a few hundred MACs each). This is the
//! serving-side mirror of the paper's training-side cache argument: the
//! frozen computation is shared, only the tiny personalized part fans out.
//!
//! `FrozenBackbone` is an `Arc<Mlp>` (THE shared backbone — the same
//! pointer the fine-tune jobs train against) plus one
//! [`ExecCtx`](crate::model::ExecCtx) of preallocated batch workspaces:
//! all activations live in matrices sized for the batch capacity, and a
//! partial flush zero-pads the tail rows instead of reallocating
//! (FC/BN-eval/ReLU are row-independent, so padded rows are simply
//! ignored).
//!
//! A lone request never waits indefinitely: [`MicroBatcher::pump`] flushes
//! when the batch fills OR when the oldest queued request has aged past a
//! configurable pump-count deadline. And the queue itself is BOUNDED:
//! [`MicroBatcher::try_submit`] rejects with a typed [`QueueFull`] once
//! `queue_bound` requests are waiting, so overload degrades into explicit
//! back-pressure instead of unbounded memory growth.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::model::{ExecCtx, Mlp};
use crate::nn::lora::LoraAdapter;
use crate::serve::registry::{AdapterRegistry, TenantId};
use crate::tensor::ops::Backend;

/// Largest supported adapter rank for the stack-allocated head buffer.
/// `FleetServer::validate_adapters` rejects `SwapAdapters` requests above
/// this, so an oversized set can never reach the serving loop's assert.
pub const MAX_RANK: usize = 32;

/// Default [`MicroBatcher`] flush deadline, in pump ticks.
pub const DEFAULT_FLUSH_DEADLINE: u64 = 2;

/// Default [`MicroBatcher`] queue bound (requests). The queue must be
/// bounded: an unbounded queue turns a load spike into unbounded memory
/// growth and unbounded tail latency instead of a typed rejection.
pub const DEFAULT_QUEUE_BOUND: usize = 1024;

/// Typed back-pressure signal: the request queue is at its bound and the
/// request was NOT enqueued. Callers surface this to the client (the
/// `FleetServer` maps it to `Response::Rejected(RejectReason::QueueFull)`)
/// rather than letting the queue grow without limit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueFull {
    /// the configured bound the queue is sitting at
    pub bound: usize,
}

/// Apply a tenant's skip-adapter set to one request row:
/// `y += Σ_k (x^k · W_A_k) · W_B_k`. Read-only on the adapters (which
/// hold weights and nothing else), so any number of rows can fan out from
/// one immutable registry snapshot.
pub fn apply_skip_adapters_row(adapters: &[LoraAdapter], xs: &[&[f32]], y: &mut [f32]) {
    assert_eq!(adapters.len(), xs.len(), "one adapter per backbone layer");
    let mut ya = [0.0f32; MAX_RANK];
    for (ad, x) in adapters.iter().zip(xs) {
        let r = ad.rank();
        assert!(r <= MAX_RANK, "adapter rank {r} exceeds MAX_RANK");
        assert_eq!(x.len(), ad.n_in(), "adapter input width mismatch");
        assert_eq!(y.len(), ad.n_out(), "adapter output width mismatch");
        ya[..r].fill(0.0);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue; // ReLU outputs are ~50% zeros
            }
            let warow = &ad.wa.data[i * r..(i + 1) * r];
            for (acc, &w) in ya[..r].iter_mut().zip(warow) {
                *acc += xi * w;
            }
        }
        let m = ad.n_out();
        for (rr, &a) in ya[..r].iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let wbrow = &ad.wb.data[rr * m..(rr + 1) * m];
            for (out, &w) in y.iter_mut().zip(wbrow) {
                *out += a * w;
            }
        }
    }
}

/// Index of the max logit.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for j in 1..xs.len() {
        if xs[j] > xs[best] {
            best = j;
        }
    }
    best
}

/// The shared frozen backbone plus one thread's batch workspaces.
pub struct FrozenBackbone {
    model: Arc<Mlp>,
    ctx: ExecCtx,
}

impl FrozenBackbone {
    /// Wrap a frozen backbone for micro-batches of up to `capacity` rows.
    /// Accepts the shared `Arc<Mlp>` directly — wrapping never copies the
    /// weights.
    pub fn new(model: impl Into<Arc<Mlp>>, backend: Backend, capacity: usize) -> Self {
        let model: Arc<Mlp> = model.into();
        // the serve stack's FINE-TUNE path (FineTuner's hidden-layer loop)
        // requires the paper's BN backbone; reject a no-BN model here, up
        // front, rather than panicking inside every adaptation job
        assert!(
            model.config.batch_norm,
            "serve path assumes the paper's BN backbone"
        );
        let ctx = ExecCtx::new(&model.config, backend, capacity);
        Self { model, ctx }
    }

    pub fn capacity(&self) -> usize {
        self.ctx.capacity()
    }

    pub fn n_in(&self) -> usize {
        self.model.config.n_in()
    }

    pub fn n_out(&self) -> usize {
        self.model.config.n_out()
    }

    pub fn n_layers(&self) -> usize {
        self.model.n_layers()
    }

    pub fn model(&self) -> &Mlp {
        &self.model
    }

    /// The shared handle (for asserting pointer identity with the
    /// fine-tune jobs' backbone in tests).
    pub fn shared_model(&self) -> &Arc<Mlp> {
        &self.model
    }

    /// Copy one request into batch row `row`.
    pub fn load_row(&mut self, row: usize, x: &[f32]) {
        self.ctx.x[0].row_mut(row).copy_from_slice(x);
    }

    /// Frozen eval forward (BN eval + ReLU) over the first `b` loaded
    /// rows; the tail rows are zero-padded so the fixed-shape kernels can
    /// run without reallocation.
    pub fn forward(&mut self, b: usize) {
        self.model.forward_frozen(&mut self.ctx, b);
    }

    /// Per-layer activation rows for request `row` (inputs x^1..x^n) —
    /// exactly what the tenant's skip adapters consume.
    pub fn activations_row(&self, row: usize) -> Vec<&[f32]> {
        self.ctx.x.iter().map(|m| m.row(row)).collect()
    }

    /// Pre-adapter output row c^n for request `row`.
    pub fn c_n_row(&self, row: usize) -> &[f32] {
        self.ctx.c_n.row(row)
    }
}

/// One queued request.
#[derive(Clone, Debug)]
pub struct BatchRequest {
    pub tenant: TenantId,
    /// caller-assigned ticket for matching responses
    pub id: u64,
    pub x: Vec<f32>,
    /// ground-truth label for feedback requests
    pub label: Option<usize>,
}

/// One served request.
#[derive(Clone, Debug)]
pub struct BatchResponse {
    pub tenant: TenantId,
    pub id: u64,
    /// the request features, echoed back for feedback buffering
    pub x: Vec<f32>,
    pub label: Option<usize>,
    pub logits: Vec<f32>,
    pub prediction: usize,
    /// adapter version used (0 = bare backbone, no adapters published)
    pub adapter_version: u64,
}

/// The micro-batching queue: requests from ANY tenant coalesce into one
/// shared backbone forward, then fan out through per-tenant adapter heads.
pub struct MicroBatcher {
    backbone: FrozenBackbone,
    registry: Arc<AdapterRegistry>,
    /// (request, pump tick at enqueue) — the tick drives the deadline
    queue: VecDeque<(BatchRequest, u64)>,
    /// hard cap on queued requests; `try_submit` rejects at the bound
    queue_bound: usize,
    /// flush when the oldest request has waited this many pump ticks
    deadline_pumps: u64,
    pump_count: u64,
    /// total micro-batches flushed
    pub batches: u64,
    /// total rows served
    pub rows: u64,
}

impl MicroBatcher {
    pub fn new(backbone: FrozenBackbone, registry: Arc<AdapterRegistry>) -> Self {
        Self::with_limits(backbone, registry, DEFAULT_FLUSH_DEADLINE, DEFAULT_QUEUE_BOUND)
    }

    /// `deadline_pumps` = 1 flushes on every pump with a non-empty queue
    /// (maximum latency-greed); larger values trade a bounded wait for
    /// better cross-tenant coalescing.
    pub fn with_deadline(
        backbone: FrozenBackbone,
        registry: Arc<AdapterRegistry>,
        deadline_pumps: u64,
    ) -> Self {
        Self::with_limits(backbone, registry, deadline_pumps, DEFAULT_QUEUE_BOUND)
    }

    /// Full-control constructor: flush deadline AND queue bound.
    pub fn with_limits(
        backbone: FrozenBackbone,
        registry: Arc<AdapterRegistry>,
        deadline_pumps: u64,
        queue_bound: usize,
    ) -> Self {
        assert!(deadline_pumps > 0, "a zero deadline would never flush");
        assert!(queue_bound > 0, "a zero queue bound would reject everything");
        Self {
            backbone,
            registry,
            queue: VecDeque::new(),
            queue_bound,
            deadline_pumps,
            pump_count: 0,
            batches: 0,
            rows: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.backbone.capacity()
    }

    pub fn n_in(&self) -> usize {
        self.backbone.n_in()
    }

    pub fn n_out(&self) -> usize {
        self.backbone.n_out()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The shared backbone handle (pointer-identity checks in tests).
    pub fn shared_model(&self) -> &Arc<Mlp> {
        self.backbone.shared_model()
    }

    /// The configured queue bound.
    pub fn queue_bound(&self) -> usize {
        self.queue_bound
    }

    /// Queue a request for the next flush, or reject it if the queue is
    /// at its bound (back-pressure: the queue can NEVER exceed
    /// `queue_bound`, so a load spike costs a typed rejection instead of
    /// unbounded memory growth).
    pub fn try_submit(&mut self, req: BatchRequest) -> Result<(), QueueFull> {
        assert_eq!(req.x.len(), self.backbone.n_in(), "request width mismatch");
        if self.queue.len() >= self.queue_bound {
            return Err(QueueFull { bound: self.queue_bound });
        }
        self.queue.push_back((req, self.pump_count));
        Ok(())
    }

    /// Queue a request, panicking at the bound — for tests and benches
    /// that size their load under the bound by construction.
    pub fn submit(&mut self, req: BatchRequest) {
        self.try_submit(req)
            .expect("micro-batch queue full (use try_submit for back-pressure)");
    }

    /// Deadline-aware flush: serve a micro-batch only when the queue has
    /// filled to capacity, or the oldest queued request has waited at
    /// least `deadline_pumps` pump ticks — so a lone request is served
    /// within a bounded number of pumps instead of waiting for a full
    /// batch that may never form. Returns the rows served (possibly 0).
    pub fn pump(&mut self, out: &mut Vec<BatchResponse>) -> usize {
        self.pump_count += 1;
        let Some(&(_, oldest)) = self.queue.front() else {
            return 0;
        };
        let full = self.queue.len() >= self.backbone.capacity();
        let expired = self.pump_count.saturating_sub(oldest) >= self.deadline_pumps;
        if full || expired {
            self.flush(out)
        } else {
            0
        }
    }

    /// Unconditional flush: serve up to `capacity` queued requests with
    /// ONE backbone forward. Appends a response per request to `out`;
    /// returns the batch size.
    pub fn flush(&mut self, out: &mut Vec<BatchResponse>) -> usize {
        let b = self.queue.len().min(self.backbone.capacity());
        if b == 0 {
            return 0;
        }
        let reqs: Vec<BatchRequest> = self.queue.drain(..b).map(|(r, _)| r).collect();
        for (row, r) in reqs.iter().enumerate() {
            self.backbone.load_row(row, &r.x);
        }
        self.backbone.forward(b);
        // one registry lock acquisition for the whole batch; rows from the
        // same tenant share one snapshot
        let snaps = self.registry.snapshot_many(reqs.iter().map(|r| r.tenant));
        for (row, req) in reqs.into_iter().enumerate() {
            let mut logits = self.backbone.c_n_row(row).to_vec();
            let adapter_version = match snaps.get(&req.tenant) {
                Some(snap) => {
                    let xs = self.backbone.activations_row(row);
                    apply_skip_adapters_row(&snap.adapters, &xs, &mut logits);
                    snap.version
                }
                None => 0, // bare backbone until the tenant publishes
            };
            let prediction = argmax(&logits);
            out.push(BatchResponse {
                tenant: req.tenant,
                id: req.id,
                x: req.x,
                label: req.label,
                logits,
                prediction,
                adapter_version,
            });
        }
        self.batches += 1;
        self.rows += b as u64;
        b
    }

    /// Flush until the queue is empty (multiple micro-batches if needed).
    pub fn flush_all(&mut self, out: &mut Vec<BatchResponse>) -> usize {
        let mut total = 0;
        loop {
            let n = self.flush(out);
            if n == 0 {
                return total;
            }
            total += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::method::Method;
    use crate::model::{AdapterSet, MlpConfig};
    use crate::tensor::Mat;
    use crate::train::FineTuner;
    use crate::util::rng::Rng;

    fn cfg() -> MlpConfig {
        MlpConfig { dims: vec![6, 10, 10, 3], rank: 2, batch_norm: true }
    }

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < tol, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn batched_rows_match_single_request_forward() {
        // one tenant's logits must be identical whether its request rides
        // in a full cross-tenant batch or runs alone
        let mut rng = Rng::new(0);
        let backbone = Arc::new(Mlp::new(&mut rng, cfg()));
        let registry = Arc::new(AdapterRegistry::new());
        // 5 tenants with distinct non-trivial adapters
        for t in 0..5u64 {
            let mut ads: Vec<LoraAdapter> = (0..3)
                .map(|k| {
                    let n_in = cfg().dims[k];
                    LoraAdapter::new(&mut rng, n_in, 2, 3)
                })
                .collect();
            for ad in ads.iter_mut() {
                for v in ad.wb.data.iter_mut() {
                    *v = 0.1 * rng.normal();
                }
            }
            registry.publish(t, ads);
        }
        let fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Blocked, 8);
        let mut batcher = MicroBatcher::new(fb, Arc::clone(&registry));

        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..6).map(|_| rng.normal()).collect())
            .collect();
        for (t, x) in xs.iter().enumerate() {
            batcher.submit(BatchRequest {
                tenant: t as u64,
                id: t as u64,
                x: x.clone(),
                label: None,
            });
        }
        let mut batched = Vec::new();
        assert_eq!(batcher.flush(&mut batched), 5);

        for (t, x) in xs.iter().enumerate() {
            let mut solo = Vec::new();
            batcher.submit(BatchRequest {
                tenant: t as u64,
                id: 100 + t as u64,
                x: x.clone(),
                label: None,
            });
            assert_eq!(batcher.flush(&mut solo), 1);
            close(&batched[t].logits, &solo[0].logits, 1e-5);
        }
    }

    #[test]
    fn matches_finetuner_predict_per_tenant() {
        // cross-check against the training-side inference path: ONE
        // shared Arc<Mlp> drives both the batcher and every per-tenant
        // FineTuner — no backbone clone anywhere
        let mut rng = Rng::new(1);
        let backbone = Arc::new(Mlp::new(&mut rng, cfg()));
        let registry = Arc::new(AdapterRegistry::new());
        let mut per_tenant: Vec<Vec<LoraAdapter>> = Vec::new();
        for t in 0..4u64 {
            let mut ads: Vec<LoraAdapter> = (0..3)
                .map(|k| LoraAdapter::new(&mut rng, cfg().dims[k], 2, 3))
                .collect();
            for ad in ads.iter_mut() {
                for v in ad.wb.data.iter_mut() {
                    *v = 0.2 * rng.normal();
                }
            }
            per_tenant.push(ads.clone());
            registry.publish(t, ads);
        }
        let fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Blocked, 4);
        let mut batcher = MicroBatcher::new(fb, registry);
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..6).map(|_| rng.normal()).collect())
            .collect();
        for (t, x) in xs.iter().enumerate() {
            batcher.submit(BatchRequest { tenant: t as u64, id: 0, x: x.clone(), label: None });
        }
        let mut out = Vec::new();
        batcher.flush(&mut out);

        for (t, x) in xs.iter().enumerate() {
            let tuner = FineTuner::new(
                Arc::clone(&backbone),
                AdapterSet::skip_from(per_tenant[t].clone()),
                Method::SkipLora,
                Backend::Blocked,
                1,
            );
            let logits = tuner.predict_alloc(&Mat::from_vec(1, 6, x.clone()));
            close(&out[t].logits, logits.row(0), 1e-4);
            assert!(Arc::ptr_eq(batcher.shared_model(), &tuner.model));
        }
    }

    #[test]
    fn partial_batches_and_unknown_tenants() {
        let mut rng = Rng::new(2);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 8);
        let mut batcher = MicroBatcher::new(fb, registry);
        let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        batcher.submit(BatchRequest { tenant: 99, id: 1, x, label: Some(2) });
        let mut out = Vec::new();
        assert_eq!(batcher.flush(&mut out), 1);
        assert_eq!(out[0].adapter_version, 0, "no adapters published yet");
        assert_eq!(out[0].label, Some(2));
        assert_eq!(out[0].logits.len(), 3);
        assert_eq!(batcher.flush(&mut out), 0, "queue drained");
    }

    #[test]
    fn flush_all_splits_into_capacity_batches() {
        let mut rng = Rng::new(3);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 4);
        let mut batcher = MicroBatcher::new(fb, registry);
        for i in 0..10u64 {
            let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            batcher.submit(BatchRequest { tenant: i, id: i, x, label: None });
        }
        let mut out = Vec::new();
        assert_eq!(batcher.flush_all(&mut out), 10);
        assert_eq!(batcher.batches, 3, "4 + 4 + 2");
        assert_eq!(batcher.rows, 10);
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn fresh_adapters_are_noop_on_logits() {
        // W_B = 0 init => published-but-untrained adapters must not change
        // predictions vs the bare backbone
        let mut rng = Rng::new(4);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let ads: Vec<LoraAdapter> = (0..3)
            .map(|k| LoraAdapter::new(&mut rng, cfg().dims[k], 2, 3))
            .collect();
        registry.publish(5, ads);
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 2);
        let mut batcher = MicroBatcher::new(fb, registry);
        let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        batcher.submit(BatchRequest { tenant: 5, id: 0, x: x.clone(), label: None });
        batcher.submit(BatchRequest { tenant: 6, id: 1, x, label: None });
        let mut out = Vec::new();
        batcher.flush(&mut out);
        assert!(out[0].adapter_version > 0);
        assert_eq!(out[1].adapter_version, 0);
        close(&out[0].logits, &out[1].logits, 1e-7);
    }

    #[test]
    fn lone_request_flushes_at_the_deadline_not_before() {
        let mut rng = Rng::new(5);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 8);
        let mut batcher = MicroBatcher::with_deadline(fb, registry, 3);
        let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        batcher.submit(BatchRequest { tenant: 1, id: 1, x, label: None });

        let mut out = Vec::new();
        // pumps 1 and 2: the lone request is younger than the deadline
        assert_eq!(batcher.pump(&mut out), 0);
        assert_eq!(batcher.pump(&mut out), 0);
        // pump 3: age reaches the deadline -> served despite batch of 1
        assert_eq!(batcher.pump(&mut out), 1);
        assert_eq!(out.len(), 1);
        assert_eq!(batcher.pending(), 0);
        // empty queue: pumps are free no-ops
        assert_eq!(batcher.pump(&mut out), 0);
    }

    #[test]
    fn queue_bound_rejects_and_never_exceeds() {
        let mut rng = Rng::new(7);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 4);
        let mut batcher = MicroBatcher::with_limits(fb, registry, 2, 6);
        let mut rejected = 0;
        for i in 0..10u64 {
            let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            let req = BatchRequest { tenant: i, id: i, x, label: None };
            match batcher.try_submit(req) {
                Ok(()) => {}
                Err(QueueFull { bound }) => {
                    assert_eq!(bound, 6);
                    rejected += 1;
                }
            }
            assert!(batcher.pending() <= batcher.queue_bound());
        }
        assert_eq!(rejected, 4, "6 admitted, 4 rejected");
        // draining frees capacity: admission resumes
        let mut out = Vec::new();
        assert_eq!(batcher.flush_all(&mut out), 6);
        let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        assert!(batcher
            .try_submit(BatchRequest { tenant: 0, id: 99, x, label: None })
            .is_ok());
    }

    #[test]
    fn full_batch_flushes_immediately_regardless_of_deadline() {
        let mut rng = Rng::new(6);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 4);
        let mut batcher = MicroBatcher::with_deadline(fb, registry, 1_000_000);
        for i in 0..4u64 {
            let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            batcher.submit(BatchRequest { tenant: i, id: i, x, label: None });
        }
        let mut out = Vec::new();
        assert_eq!(batcher.pump(&mut out), 4, "capacity reached: no waiting");
    }
}
