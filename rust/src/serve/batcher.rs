//! Cross-tenant micro-batching.
//!
//! The Skip-LoRA serving identity (Eq. 17): for every tenant t,
//!
//! ```text
//! logits_t(x) = c^n(x) + Σ_k adapter_{t,k}(x^k)
//! ```
//!
//! where `c^n` and the activations `x^k` depend ONLY on the shared frozen
//! backbone — not on the tenant. So B requests from B different tenants
//! cost ONE backbone forward (the expensive dense part, computed batched)
//! plus B rank-r adapter heads (a few hundred MACs each). This is the
//! serving-side mirror of the paper's training-side cache argument: the
//! frozen computation is shared, only the tiny personalized part fans out.
//!
//! `FrozenBackbone` is an `Arc<Mlp>` (THE shared backbone — the same
//! pointer the fine-tune jobs train against) plus one
//! [`ExecCtx`](crate::model::ExecCtx) of preallocated batch workspaces:
//! all activations live in matrices sized for the batch capacity, and a
//! partial flush zero-pads the tail rows instead of reallocating
//! (FC/BN-eval/ReLU are row-independent, so padded rows are simply
//! ignored).
//!
//! A lone request never waits indefinitely: [`MicroBatcher::pump`] flushes
//! when the batch fills OR when the oldest queued request has aged past a
//! configurable pump-count deadline. And the queue itself is BOUNDED:
//! [`MicroBatcher::try_submit`] rejects with a typed
//! [`SubmitError::QueueFull`] once `queue_bound` requests are waiting, so
//! overload degrades into explicit back-pressure instead of unbounded
//! memory growth.
//!
//! ## The zero-alloc tenant-grouped flush (DESIGN.md §10)
//!
//! [`MicroBatcher::flush`] fans a batch out by TENANT GROUP, not by row:
//! rows are sorted by tenant (an index sort over a reusable `u32`
//! buffer), each tenant's rows are gathered into contiguous sub-batch
//! scratch, every skip adapter runs as TWO small GEMMs
//! (`Ya = Xsub·W_A`, `logits_sub += Ya·W_B`) instead of per-row rank-r
//! GEMV chains, and the group's logits scatter back. All scratch — the
//! staged requests, the registry snapshot batch, the gather/sub-batch
//! matrices, the logits staging — lives in capacity-sized buffers owned
//! by the batcher, so a warm flush performs **zero heap allocations**
//! (proved by the counting-allocator test in `tests/zero_alloc.rs`).
//! Every kernel on the path preserves the per-row reference's
//! accumulation order, so grouping moves zero ulps
//! (`tests/kernel_equiv.rs`); the pre-grouping path survives as
//! [`MicroBatcher::flush_reference`] — the correctness oracle and the
//! `benches/serve_micro.rs` baseline.
//!
//! ## Observability (DESIGN.md §11)
//!
//! The flush decomposes into [`FlushStage`] spans (staging → backbone
//! forward → snapshot → gather → adapter fan-out → scatter → emit),
//! accumulated in the batcher's [`FlushStages`] fixed arrays, and
//! [`MicroBatcher::flush_traced`] additionally records
//! `FlushStart`/`FanoutTenant`/`FlushEnd` events into a caller-owned
//! [`FlightRecorder`]. Both are allocation-free: the zero-alloc proof in
//! `tests/zero_alloc.rs` runs with BOTH enabled.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::model::{ExecCtx, Mlp};
use crate::nn::lora::LoraAdapter;
use crate::obs::stages::{FlushStage, FlushStages};
use crate::obs::trace::{EventKind, FlightRecorder};
use crate::serve::registry::{AdapterRegistry, SnapshotBatch, TenantId};
use crate::tensor::ops::Backend;
use crate::tensor::Mat;

/// Largest supported adapter rank for the serving scratch buffers.
/// `FleetServer::validate_adapters` rejects `SwapAdapters` requests above
/// this, so an oversized set can never reach the serving loop's assert.
pub const MAX_RANK: usize = 32;

/// Default [`MicroBatcher`] flush deadline, in pump ticks.
pub const DEFAULT_FLUSH_DEADLINE: u64 = 2;

/// Default [`MicroBatcher`] queue bound (requests). The queue must be
/// bounded: an unbounded queue turns a load spike into unbounded memory
/// growth and unbounded tail latency instead of a typed rejection.
pub const DEFAULT_QUEUE_BOUND: usize = 1024;

/// Why [`MicroBatcher::try_submit`] turned a request away — typed, so a
/// direct batcher user can react (back off vs fix the request) and so
/// bad input can never panic the pump loop. The `FleetServer` maps these
/// onto `RejectReason::{QueueFull, Malformed}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// the request queue is at its configured bound and the request was
    /// NOT enqueued — back-pressure, retry later
    QueueFull {
        /// the configured bound the queue is sitting at
        bound: usize,
    },
    /// the request's feature width doesn't match the deployed backbone —
    /// the request itself is malformed and a retry cannot succeed
    WidthMismatch { expected: usize, got: usize },
}

/// Apply a tenant's skip-adapter set to one request row:
/// `y += Σ_k (x^k · W_A_k) · W_B_k`. Read-only on the adapters (which
/// hold weights and nothing else), so any number of rows can fan out from
/// one immutable registry snapshot.
///
/// This is the PER-ROW REFERENCE: the serving hot path now applies
/// adapters tenant-grouped ([`LoraAdapter::forward_grouped`] inside
/// [`MicroBatcher::flush`]), which produces bit-identical logits — the
/// grouped GEMMs keep this function's accumulation order element for
/// element. Kept callable for the equivalence tests and as the
/// `benches/serve_micro.rs` baseline ([`MicroBatcher::flush_reference`]).
pub fn apply_skip_adapters_row(adapters: &[LoraAdapter], xs: &[&[f32]], y: &mut [f32]) {
    assert_eq!(adapters.len(), xs.len(), "one adapter per backbone layer");
    let mut ya = [0.0f32; MAX_RANK];
    for (ad, x) in adapters.iter().zip(xs) {
        let r = ad.rank();
        assert!(r <= MAX_RANK, "adapter rank {r} exceeds MAX_RANK");
        assert_eq!(x.len(), ad.n_in(), "adapter input width mismatch");
        assert_eq!(y.len(), ad.n_out(), "adapter output width mismatch");
        ya[..r].fill(0.0);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue; // ReLU outputs are ~50% zeros
            }
            let warow = &ad.wa.data[i * r..(i + 1) * r];
            for (acc, &w) in ya[..r].iter_mut().zip(warow) {
                *acc += xi * w;
            }
        }
        let m = ad.n_out();
        for (rr, &a) in ya[..r].iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let wbrow = &ad.wb.data[rr * m..(rr + 1) * m];
            for (out, &w) in y.iter_mut().zip(wbrow) {
                *out += a * w;
            }
        }
    }
}

/// Index of the max logit.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for j in 1..xs.len() {
        if xs[j] > xs[best] {
            best = j;
        }
    }
    best
}

/// The shared frozen backbone plus one thread's batch workspaces.
pub struct FrozenBackbone {
    model: Arc<Mlp>,
    ctx: ExecCtx,
}

impl FrozenBackbone {
    /// Wrap a frozen backbone for micro-batches of up to `capacity` rows.
    /// Accepts the shared `Arc<Mlp>` directly — wrapping never copies the
    /// weights.
    pub fn new(model: impl Into<Arc<Mlp>>, backend: Backend, capacity: usize) -> Self {
        let model: Arc<Mlp> = model.into();
        // the serve stack's FINE-TUNE path (FineTuner's hidden-layer loop)
        // requires the paper's BN backbone; reject a no-BN model here, up
        // front, rather than panicking inside every adaptation job
        assert!(
            model.config.batch_norm,
            "serve path assumes the paper's BN backbone"
        );
        let ctx = ExecCtx::new(&model.config, backend, capacity);
        Self { model, ctx }
    }

    pub fn capacity(&self) -> usize {
        self.ctx.capacity()
    }

    pub fn n_in(&self) -> usize {
        self.model.config.n_in()
    }

    pub fn n_out(&self) -> usize {
        self.model.config.n_out()
    }

    pub fn n_layers(&self) -> usize {
        self.model.n_layers()
    }

    pub fn model(&self) -> &Mlp {
        &self.model
    }

    /// The shared handle (for asserting pointer identity with the
    /// fine-tune jobs' backbone in tests).
    pub fn shared_model(&self) -> &Arc<Mlp> {
        &self.model
    }

    /// Copy one request into batch row `row`.
    pub fn load_row(&mut self, row: usize, x: &[f32]) {
        self.ctx.x[0].row_mut(row).copy_from_slice(x);
    }

    /// Frozen eval forward (BN eval + ReLU) over the first `b` loaded
    /// rows; the tail rows are zero-padded so the fixed-shape kernels can
    /// run without reallocation.
    pub fn forward(&mut self, b: usize) {
        self.model.forward_frozen(&mut self.ctx, b);
    }

    /// Per-layer activation rows for request `row` (inputs x^1..x^n) —
    /// exactly what the tenant's skip adapters consume. Allocates a
    /// `Vec` of slices per call: REFERENCE/BASELINE PATH ONLY — the hot
    /// path gathers tenant groups into contiguous scratch instead
    /// (`apply_adapters_grouped`).
    pub fn activations_row(&self, row: usize) -> Vec<&[f32]> {
        self.ctx.x.iter().map(|m| m.row(row)).collect()
    }

    /// Pre-adapter output row c^n for request `row`.
    pub fn c_n_row(&self, row: usize) -> &[f32] {
        self.ctx.c_n.row(row)
    }

    /// Stage the first `b` pre-adapter rows (c^n) into the context's
    /// logits workspace, where the grouped fan-out accumulates adapter
    /// deltas in place. One contiguous copy, no per-row `to_vec`.
    fn stage_logits(&mut self, b: usize) {
        let n_out = self.ctx.c_n.cols;
        self.ctx.logits.data[..b * n_out].copy_from_slice(&self.ctx.c_n.data[..b * n_out]);
    }

    /// Apply one tenant's skip adapters to its gathered row group:
    /// gather `rows` from each layer's activations into `xsub[k]`, run
    /// the adapter pair as two sub-batch GEMMs, and scatter the group's
    /// logits back. All buffers are capacity-sized and reshaped in
    /// place — zero allocations.
    #[allow(clippy::too_many_arguments)]
    fn apply_adapters_grouped(
        &mut self,
        rows: &[u32],
        adapters: &[LoraAdapter],
        xsub: &mut [Mat],
        ya: &mut Mat,
        logits_sub: &mut Mat,
        stages: &mut FlushStages,
    ) {
        let g = rows.len();
        let n_out = self.ctx.logits.cols;
        assert_eq!(adapters.len(), self.ctx.x.len(), "one adapter per backbone layer");
        let t = stages.span();
        logits_sub.set_logical(g, n_out);
        for (gi, &r) in rows.iter().enumerate() {
            logits_sub.row_mut(gi).copy_from_slice(self.ctx.logits.row(r as usize));
        }
        stages.add(FlushStage::Gather, t);
        for (k, ad) in adapters.iter().enumerate() {
            assert!(ad.rank() <= MAX_RANK, "adapter rank {} exceeds MAX_RANK", ad.rank());
            let xk = &self.ctx.x[k];
            let xs = &mut xsub[k];
            let t = stages.span();
            xs.set_logical(g, xk.cols);
            for (gi, &r) in rows.iter().enumerate() {
                xs.row_mut(gi).copy_from_slice(xk.row(r as usize));
            }
            stages.add(FlushStage::Gather, t);
            let t = stages.span();
            ad.forward_grouped(self.ctx.backend, xs, ya, logits_sub);
            stages.add(FlushStage::AdapterFanout, t);
        }
        let t = stages.span();
        for (gi, &r) in rows.iter().enumerate() {
            self.ctx.logits.row_mut(r as usize).copy_from_slice(logits_sub.row(gi));
        }
        stages.add(FlushStage::Scatter, t);
    }
}

/// One queued request.
#[derive(Clone, Debug)]
pub struct BatchRequest {
    pub tenant: TenantId,
    /// caller-assigned ticket for matching responses
    pub id: u64,
    pub x: Vec<f32>,
    /// ground-truth label for feedback requests
    pub label: Option<usize>,
}

/// One served request. Deliberately allocation-free to produce: the
/// request features move back out ONLY for feedback requests (the only
/// consumer — `FleetServer::apply_feedback`'s buffer push), and logits
/// live in the batcher's staging matrix ([`MicroBatcher::last_logits`],
/// indexed by `row`) instead of a per-response `Vec`.
#[derive(Clone, Debug)]
pub struct BatchResponse {
    pub tenant: TenantId,
    pub id: u64,
    /// this response's row in the flushed batch — indexes
    /// [`MicroBatcher::last_logits`] until the next flush
    pub row: usize,
    /// the flush ordinal (`MicroBatcher::batches` at serve time) this
    /// `row` belongs to — [`MicroBatcher::logits_for`] checks it, so a
    /// response accumulated across multiple flushes (`flush_all`) can
    /// never silently read another request's logits out of the reused
    /// staging matrix
    pub batch: u64,
    /// the request features, moved back ONLY for feedback requests
    /// (`label.is_some()`); `None` for plain predicts, whose `x` nobody
    /// downstream reads
    pub x: Option<Vec<f32>>,
    pub label: Option<usize>,
    pub prediction: usize,
    /// adapter version used (0 = bare backbone, no adapters published)
    pub adapter_version: u64,
}

/// Reusable scratch for the tenant-grouped fan-out: the row-order index,
/// the per-layer sub-batch gather matrices, the rank workspace, and the
/// group logits staging. Everything is capacity-sized at construction
/// and reshaped in place per group (`Mat::set_logical`) — a warm flush
/// never touches the allocator.
struct FanoutScratch {
    /// batch row indices, sorted by tenant before grouping
    order: Vec<u32>,
    /// xsub[k]: capacity × dims[k] gather buffer for layer k's inputs
    xsub: Vec<Mat>,
    /// capacity × MAX_RANK workspace for Ya = Xsub·W_A
    ya: Mat,
    /// capacity × n_out staging for the group's logits
    logits_sub: Mat,
}

impl FanoutScratch {
    fn new(dims: &[usize], capacity: usize) -> Self {
        let n_out = *dims.last().expect("at least one layer");
        Self {
            order: Vec::with_capacity(capacity),
            xsub: dims[..dims.len() - 1]
                .iter()
                .map(|&d| Mat::zeros(capacity, d))
                .collect(),
            ya: Mat::zeros(capacity, MAX_RANK),
            logits_sub: Mat::zeros(capacity, n_out),
        }
    }
}

/// The micro-batching queue: requests from ANY tenant coalesce into one
/// shared backbone forward, then fan out through per-tenant adapter heads.
pub struct MicroBatcher {
    backbone: FrozenBackbone,
    registry: Arc<AdapterRegistry>,
    /// (request, pump tick at enqueue) — the tick drives the deadline
    queue: VecDeque<(BatchRequest, u64)>,
    /// hard cap on queued requests; `try_submit` rejects at the bound
    queue_bound: usize,
    /// flush when the oldest request has waited this many pump ticks
    deadline_pumps: u64,
    pump_count: u64,
    /// total micro-batches flushed
    pub batches: u64,
    /// total rows served
    pub rows: u64,
    /// requests staged for the in-flight flush (reusable)
    staged: Vec<BatchRequest>,
    /// reusable registry batch-lookup scratch (one lock per shard)
    snaps: SnapshotBatch,
    /// reusable tenant-grouped fan-out scratch
    fanout: FanoutScratch,
    /// per-stage flush attribution (fixed arrays — allocation-free)
    stages: FlushStages,
}

impl MicroBatcher {
    pub fn new(backbone: FrozenBackbone, registry: Arc<AdapterRegistry>) -> Self {
        Self::with_limits(backbone, registry, DEFAULT_FLUSH_DEADLINE, DEFAULT_QUEUE_BOUND)
    }

    /// `deadline_pumps` = 1 flushes on every pump with a non-empty queue
    /// (maximum latency-greed); larger values trade a bounded wait for
    /// better cross-tenant coalescing.
    pub fn with_deadline(
        backbone: FrozenBackbone,
        registry: Arc<AdapterRegistry>,
        deadline_pumps: u64,
    ) -> Self {
        Self::with_limits(backbone, registry, deadline_pumps, DEFAULT_QUEUE_BOUND)
    }

    /// Full-control constructor: flush deadline AND queue bound.
    pub fn with_limits(
        backbone: FrozenBackbone,
        registry: Arc<AdapterRegistry>,
        deadline_pumps: u64,
        queue_bound: usize,
    ) -> Self {
        assert!(deadline_pumps > 0, "a zero deadline would never flush");
        assert!(queue_bound > 0, "a zero queue bound would reject everything");
        let capacity = backbone.capacity();
        let fanout = FanoutScratch::new(&backbone.model.config.dims, capacity);
        Self {
            backbone,
            registry,
            queue: VecDeque::new(),
            queue_bound,
            deadline_pumps,
            pump_count: 0,
            batches: 0,
            rows: 0,
            staged: Vec::with_capacity(capacity),
            snaps: SnapshotBatch::new(),
            fanout,
            stages: FlushStages::new(true),
        }
    }

    /// Per-stage flush timers (read-only view).
    pub fn stages(&self) -> &FlushStages {
        &self.stages
    }

    /// Toggle stage timing. On (the default) costs two monotonic clock
    /// reads per stage into fixed arrays; off costs one branch per site.
    pub fn set_stage_timing(&mut self, enabled: bool) {
        self.stages.set_enabled(enabled);
    }

    pub fn capacity(&self) -> usize {
        self.backbone.capacity()
    }

    pub fn n_in(&self) -> usize {
        self.backbone.n_in()
    }

    pub fn n_out(&self) -> usize {
        self.backbone.n_out()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The shared backbone handle (pointer-identity checks in tests).
    pub fn shared_model(&self) -> &Arc<Mlp> {
        self.backbone.shared_model()
    }

    /// The configured queue bound.
    pub fn queue_bound(&self) -> usize {
        self.queue_bound
    }

    /// Queue a request for the next flush, or reject it with a typed
    /// error: `QueueFull` when the bounded queue is at its limit
    /// (back-pressure — the queue can NEVER exceed `queue_bound`, so a
    /// load spike costs a rejection instead of unbounded memory growth)
    /// or `WidthMismatch` when the features don't fit the backbone (a
    /// direct batcher user's bad input must not panic the pump loop).
    pub fn try_submit(&mut self, req: BatchRequest) -> Result<(), SubmitError> {
        let expected = self.backbone.n_in();
        if req.x.len() != expected {
            return Err(SubmitError::WidthMismatch { expected, got: req.x.len() });
        }
        if self.queue.len() >= self.queue_bound {
            return Err(SubmitError::QueueFull { bound: self.queue_bound });
        }
        self.queue.push_back((req, self.pump_count));
        Ok(())
    }

    /// Queue a request, panicking on rejection — for tests and benches
    /// that size their load (and shape their requests) correctly by
    /// construction.
    pub fn submit(&mut self, req: BatchRequest) {
        if let Err(e) = self.try_submit(req) {
            panic!("submit rejected (use try_submit for typed handling): {e:?}");
        }
    }

    /// Deadline-aware flush: serve a micro-batch only when the queue has
    /// filled to capacity, or the oldest queued request has waited at
    /// least `deadline_pumps` pump ticks — so a lone request is served
    /// within a bounded number of pumps instead of waiting for a full
    /// batch that may never form. Returns the rows served (possibly 0).
    pub fn pump(&mut self, out: &mut Vec<BatchResponse>) -> usize {
        self.pump_traced(out, None)
    }

    /// Would the NEXT [`MicroBatcher::pump`] flush? Evaluates the same
    /// fullness-or-deadline predicate `pump_traced` will apply after it
    /// advances the pump clock, without side effects — the multi-lane
    /// driver uses this to decide whether a tick is worth fanning out to
    /// scoped threads (`serve::lanes`).
    pub fn flush_due(&self) -> bool {
        let Some(&(_, oldest)) = self.queue.front() else {
            return false;
        };
        self.queue.len() >= self.backbone.capacity()
            || (self.pump_count + 1).saturating_sub(oldest) >= self.deadline_pumps
    }

    /// `pump` with an optional flight recorder for the flush events.
    pub fn pump_traced(
        &mut self,
        out: &mut Vec<BatchResponse>,
        trace: Option<&mut FlightRecorder>,
    ) -> usize {
        self.pump_count += 1;
        let Some(&(_, oldest)) = self.queue.front() else {
            return 0;
        };
        let full = self.queue.len() >= self.backbone.capacity();
        let expired = self.pump_count.saturating_sub(oldest) >= self.deadline_pumps;
        if full || expired {
            self.flush_traced(out, trace)
        } else {
            0
        }
    }

    /// Unconditional flush: serve up to `capacity` queued requests with
    /// ONE backbone forward and a TENANT-GROUPED adapter fan-out.
    /// Appends a response per request to `out` (original submit order);
    /// returns the batch size.
    ///
    /// Hot-path discipline: every buffer this touches is owned and
    /// capacity-sized — a warm flush performs zero heap allocations
    /// (`tests/zero_alloc.rs` proves it under a counting allocator), and
    /// its logits are bit-identical to [`MicroBatcher::flush_reference`]
    /// (`tests/kernel_equiv.rs`).
    pub fn flush(&mut self, out: &mut Vec<BatchResponse>) -> usize {
        self.flush_traced(out, None)
    }

    /// `flush` with an optional flight recorder: records
    /// `FlushStart { pending }`, one `FanoutTenant { tenant, rows }` per
    /// tenant group, and `FlushEnd { rows, ns }` — all copy-only into the
    /// recorder's preallocated ring, so the zero-alloc guarantee holds
    /// with tracing on.
    pub fn flush_traced(
        &mut self,
        out: &mut Vec<BatchResponse>,
        mut trace: Option<&mut FlightRecorder>,
    ) -> usize {
        if self.queue.is_empty() {
            return 0;
        }
        // the whole-flush span: the stage spans below are disjoint
        // sub-intervals of it, measured by the same clock, so their sum
        // reconciles against this total (and against the server's
        // batch_forward histogram, which records exactly this value)
        let t_flush = self.stages.span();
        if let Some(rec) = trace.as_deref_mut() {
            rec.record(EventKind::FlushStart { pending: self.queue.len() as u32 });
        }
        let b = self.stage_and_forward(true);
        debug_assert!(b > 0, "non-empty queue must stage at least one row");
        // one registry lock acquisition per DISTINCT shard for the whole
        // batch; rows from the same tenant share one snapshot
        let t = self.stages.span();
        self.registry
            .snapshot_many_into(self.staged.iter().map(|r| r.tenant), &mut self.snaps);
        self.stages.add(FlushStage::Snapshot, t);
        let t = self.stages.span();
        self.backbone.stage_logits(b);
        self.stages.add(FlushStage::Staging, t);
        // group rows by tenant: sort the row-index scratch, then walk runs
        let FanoutScratch { order, xsub, ya, logits_sub } = &mut self.fanout;
        let t = self.stages.span();
        order.clear();
        order.extend(0..b as u32);
        let staged = &self.staged;
        order.sort_unstable_by_key(|&r| staged[r as usize].tenant);
        self.stages.add(FlushStage::Gather, t);
        let mut i = 0;
        while i < b {
            let tenant = self.staged[order[i] as usize].tenant;
            let mut j = i + 1;
            while j < b && self.staged[order[j] as usize].tenant == tenant {
                j += 1;
            }
            if let Some(snap) = self.snaps.get(tenant) {
                self.backbone.apply_adapters_grouped(
                    &order[i..j],
                    &snap.adapters,
                    xsub,
                    ya,
                    logits_sub,
                    &mut self.stages,
                );
            }
            // tenants with nothing published serve the bare backbone
            // logits already staged
            if let Some(rec) = trace.as_deref_mut() {
                rec.record(EventKind::FanoutTenant { tenant, rows: (j - i) as u32 });
            }
            i = j;
        }
        let t = self.stages.span();
        self.emit_responses(b, out);
        self.stages.add(FlushStage::Emit, t);
        self.stages.finish_flush(t_flush);
        if let Some(rec) = trace.as_deref_mut() {
            rec.record(EventKind::FlushEnd {
                rows: b as u32,
                ns: self.stages.last_total_ns().unwrap_or(0),
            });
        }
        b
    }

    /// The pre-grouping per-row fan-out, kept VERBATIM: one backbone
    /// forward, then per row a logits `to_vec`, an activation-slice
    /// `Vec`, and a rank-r GEMV chain ([`apply_skip_adapters_row`]).
    /// This is (a) the reference `flush` is bit-equivalence-tested
    /// against and (b) the baseline `benches/serve_micro.rs` measures
    /// the tenant-grouped speedup from. Not for production use.
    pub fn flush_reference(&mut self, out: &mut Vec<BatchResponse>) -> usize {
        let b = self.stage_and_forward(false);
        if b == 0 {
            return 0;
        }
        self.registry
            .snapshot_many_into(self.staged.iter().map(|r| r.tenant), &mut self.snaps);
        for row in 0..b {
            let mut logits = self.backbone.c_n_row(row).to_vec();
            if let Some(snap) = self.snaps.get(self.staged[row].tenant) {
                let xs = self.backbone.activations_row(row);
                apply_skip_adapters_row(&snap.adapters, &xs, &mut logits);
            }
            self.backbone.ctx.logits.row_mut(row).copy_from_slice(&logits);
        }
        self.emit_responses(b, out);
        b
    }

    /// Shared flush front half: move up to `capacity` queued requests
    /// into the staging buffer, load their rows, run the ONE shared
    /// frozen forward. Returns the batch size. `timed` attributes the
    /// staging and forward spans (the traced flush passes true; the
    /// reference flush stays unattributed so its stage sums can never
    /// outgrow a flush total it doesn't record).
    fn stage_and_forward(&mut self, timed: bool) -> usize {
        let b = self.queue.len().min(self.backbone.capacity());
        if b == 0 {
            return 0;
        }
        let t = if timed { self.stages.span() } else { None };
        self.staged.clear();
        self.staged.extend(self.queue.drain(..b).map(|(r, _)| r));
        for (row, r) in self.staged.iter().enumerate() {
            self.backbone.load_row(row, &r.x);
        }
        self.stages.add(FlushStage::Staging, t);
        let t = if timed { self.stages.span() } else { None };
        self.backbone.forward(b);
        self.stages.add(FlushStage::BackboneForward, t);
        b
    }

    /// Shared flush back half: drain the staged requests into responses
    /// (predictions read from the logits staging; `x` moves back only
    /// for feedback requests) and bump the counters.
    fn emit_responses(&mut self, b: usize, out: &mut Vec<BatchResponse>) {
        self.batches += 1;
        self.rows += b as u64;
        for (row, req) in self.staged.drain(..).enumerate() {
            let prediction = argmax(self.backbone.ctx.logits.row(row));
            let adapter_version = self.snaps.get(req.tenant).map_or(0, |s| s.version);
            let BatchRequest { tenant, id, x, label } = req;
            out.push(BatchResponse {
                tenant,
                id,
                row,
                batch: self.batches,
                x: if label.is_some() { Some(x) } else { None },
                label,
                prediction,
                adapter_version,
            });
        }
    }

    /// The logits of the most recent flush, row-indexed by
    /// [`BatchResponse::row`]. ONLY valid for responses of that flush —
    /// the staging matrix is reused, so responses accumulated across
    /// multiple flushes (e.g. `flush_all`) must go through the checked
    /// [`MicroBatcher::logits_for`] instead. `FleetServer` consumers
    /// should read predictions off the responses.
    pub fn last_logits(&self) -> &Mat {
        &self.backbone.ctx.logits
    }

    /// Logits for `resp`, or `None` if a later flush has already reused
    /// the staging matrix (the response's [`BatchResponse::batch`] stamp
    /// no longer matches) — reading a stale row can never silently
    /// return another request's logits.
    pub fn logits_for(&self, resp: &BatchResponse) -> Option<&[f32]> {
        (resp.batch == self.batches).then(|| self.backbone.ctx.logits.row(resp.row))
    }

    /// Flush until the queue is empty (multiple micro-batches if needed).
    pub fn flush_all(&mut self, out: &mut Vec<BatchResponse>) -> usize {
        let mut total = 0;
        loop {
            let n = self.flush(out);
            if n == 0 {
                return total;
            }
            total += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::method::Method;
    use crate::model::{AdapterSet, MlpConfig};
    use crate::tensor::Mat;
    use crate::train::FineTuner;
    use crate::util::rng::Rng;

    fn cfg() -> MlpConfig {
        MlpConfig { dims: vec![6, 10, 10, 3], rank: 2, batch_norm: true }
    }

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < tol, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn batched_rows_match_single_request_forward() {
        // one tenant's logits must be identical whether its request rides
        // in a full cross-tenant batch or runs alone
        let mut rng = Rng::new(0);
        let backbone = Arc::new(Mlp::new(&mut rng, cfg()));
        let registry = Arc::new(AdapterRegistry::new());
        // 5 tenants with distinct non-trivial adapters
        for t in 0..5u64 {
            let mut ads: Vec<LoraAdapter> = (0..3)
                .map(|k| {
                    let n_in = cfg().dims[k];
                    LoraAdapter::new(&mut rng, n_in, 2, 3)
                })
                .collect();
            for ad in ads.iter_mut() {
                for v in ad.wb.data.iter_mut() {
                    *v = 0.1 * rng.normal();
                }
            }
            registry.publish(t, ads);
        }
        let fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Blocked, 8);
        let mut batcher = MicroBatcher::new(fb, Arc::clone(&registry));

        let xs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..6).map(|_| rng.normal()).collect())
            .collect();
        for (t, x) in xs.iter().enumerate() {
            batcher.submit(BatchRequest {
                tenant: t as u64,
                id: t as u64,
                x: x.clone(),
                label: None,
            });
        }
        let mut batched = Vec::new();
        assert_eq!(batcher.flush(&mut batched), 5);
        let batched_logits: Vec<Vec<f32>> = batched
            .iter()
            .map(|r| batcher.last_logits().row(r.row).to_vec())
            .collect();

        for (t, x) in xs.iter().enumerate() {
            let mut solo = Vec::new();
            batcher.submit(BatchRequest {
                tenant: t as u64,
                id: 100 + t as u64,
                x: x.clone(),
                label: None,
            });
            assert_eq!(batcher.flush(&mut solo), 1);
            // same kernels, row-independent: batched == solo EXACTLY
            assert_eq!(
                batched_logits[t],
                batcher.last_logits().row(solo[0].row),
                "tenant {t} drifted between batched and solo serving"
            );
        }
    }

    #[test]
    fn matches_finetuner_predict_per_tenant() {
        // cross-check against the training-side inference path: ONE
        // shared Arc<Mlp> drives both the batcher and every per-tenant
        // FineTuner — no backbone clone anywhere
        let mut rng = Rng::new(1);
        let backbone = Arc::new(Mlp::new(&mut rng, cfg()));
        let registry = Arc::new(AdapterRegistry::new());
        let mut per_tenant: Vec<Vec<LoraAdapter>> = Vec::new();
        for t in 0..4u64 {
            let mut ads: Vec<LoraAdapter> = (0..3)
                .map(|k| LoraAdapter::new(&mut rng, cfg().dims[k], 2, 3))
                .collect();
            for ad in ads.iter_mut() {
                for v in ad.wb.data.iter_mut() {
                    *v = 0.2 * rng.normal();
                }
            }
            per_tenant.push(ads.clone());
            registry.publish(t, ads);
        }
        let fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Blocked, 4);
        let mut batcher = MicroBatcher::new(fb, registry);
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..6).map(|_| rng.normal()).collect())
            .collect();
        for (t, x) in xs.iter().enumerate() {
            batcher.submit(BatchRequest { tenant: t as u64, id: 0, x: x.clone(), label: None });
        }
        let mut out = Vec::new();
        batcher.flush(&mut out);

        for (t, x) in xs.iter().enumerate() {
            let tuner = FineTuner::new(
                Arc::clone(&backbone),
                AdapterSet::skip_from(per_tenant[t].clone()),
                Method::SkipLora,
                Backend::Blocked,
                1,
            );
            let logits = tuner.predict_alloc(&Mat::from_vec(1, 6, x.clone()));
            close(batcher.last_logits().row(out[t].row), logits.row(0), 1e-4);
            assert!(Arc::ptr_eq(batcher.shared_model(), &tuner.model));
        }
    }

    #[test]
    fn partial_batches_and_unknown_tenants() {
        let mut rng = Rng::new(2);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 8);
        let mut batcher = MicroBatcher::new(fb, registry);
        let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        batcher.submit(BatchRequest { tenant: 99, id: 1, x: x.clone(), label: Some(2) });
        batcher.submit(BatchRequest { tenant: 98, id: 2, x, label: None });
        let mut out = Vec::new();
        assert_eq!(batcher.flush(&mut out), 2);
        assert_eq!(out[0].adapter_version, 0, "no adapters published yet");
        assert_eq!(out[0].label, Some(2));
        assert!(out[0].x.is_some(), "feedback requests carry x back");
        assert!(out[1].x.is_none(), "predicts do not echo x");
        assert_eq!(batcher.last_logits().cols, 3);
        assert_eq!(batcher.flush(&mut out), 0, "queue drained");
    }

    #[test]
    fn flush_all_splits_into_capacity_batches() {
        let mut rng = Rng::new(3);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 4);
        let mut batcher = MicroBatcher::new(fb, registry);
        for i in 0..10u64 {
            let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            batcher.submit(BatchRequest { tenant: i, id: i, x, label: None });
        }
        let mut out = Vec::new();
        assert_eq!(batcher.flush_all(&mut out), 10);
        assert_eq!(batcher.batches, 3, "4 + 4 + 2");
        assert_eq!(batcher.rows, 10);
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn fresh_adapters_are_noop_on_logits() {
        // W_B = 0 init => published-but-untrained adapters must not change
        // predictions vs the bare backbone
        let mut rng = Rng::new(4);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let ads: Vec<LoraAdapter> = (0..3)
            .map(|k| LoraAdapter::new(&mut rng, cfg().dims[k], 2, 3))
            .collect();
        registry.publish(5, ads);
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 2);
        let mut batcher = MicroBatcher::new(fb, registry);
        let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        batcher.submit(BatchRequest { tenant: 5, id: 0, x: x.clone(), label: None });
        batcher.submit(BatchRequest { tenant: 6, id: 1, x, label: None });
        let mut out = Vec::new();
        batcher.flush(&mut out);
        assert!(out[0].adapter_version > 0);
        assert_eq!(out[1].adapter_version, 0);
        let logits = batcher.last_logits();
        close(logits.row(out[0].row), logits.row(out[1].row), 1e-7);
    }

    #[test]
    fn lone_request_flushes_at_the_deadline_not_before() {
        let mut rng = Rng::new(5);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 8);
        let mut batcher = MicroBatcher::with_deadline(fb, registry, 3);
        let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        batcher.submit(BatchRequest { tenant: 1, id: 1, x, label: None });

        let mut out = Vec::new();
        // pumps 1 and 2: the lone request is younger than the deadline
        assert_eq!(batcher.pump(&mut out), 0);
        assert_eq!(batcher.pump(&mut out), 0);
        // pump 3: age reaches the deadline -> served despite batch of 1
        assert_eq!(batcher.pump(&mut out), 1);
        assert_eq!(out.len(), 1);
        assert_eq!(batcher.pending(), 0);
        // empty queue: pumps are free no-ops
        assert_eq!(batcher.pump(&mut out), 0);
    }

    #[test]
    fn queue_bound_rejects_and_never_exceeds() {
        let mut rng = Rng::new(7);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 4);
        let mut batcher = MicroBatcher::with_limits(fb, registry, 2, 6);
        let mut rejected = 0;
        for i in 0..10u64 {
            let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            let req = BatchRequest { tenant: i, id: i, x, label: None };
            match batcher.try_submit(req) {
                Ok(()) => {}
                Err(SubmitError::QueueFull { bound }) => {
                    assert_eq!(bound, 6);
                    rejected += 1;
                }
                Err(other) => panic!("unexpected rejection {other:?}"),
            }
            assert!(batcher.pending() <= batcher.queue_bound());
        }
        assert_eq!(rejected, 4, "6 admitted, 4 rejected");
        // draining frees capacity: admission resumes
        let mut out = Vec::new();
        assert_eq!(batcher.flush_all(&mut out), 6);
        let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        assert!(batcher
            .try_submit(BatchRequest { tenant: 0, id: 99, x, label: None })
            .is_ok());
    }

    #[test]
    fn logits_for_rejects_rows_from_earlier_flushes() {
        // the staging matrix is reused per flush: responses accumulated
        // across flush_all must not silently read a later batch's logits
        let mut rng = Rng::new(10);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Packed, 4);
        let mut batcher = MicroBatcher::new(fb, registry);
        for i in 0..6u64 {
            let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            batcher.submit(BatchRequest { tenant: i, id: i, x, label: None });
        }
        let mut out = Vec::new();
        assert_eq!(batcher.flush_all(&mut out), 6, "4 + 2 across two flushes");
        // first-batch rows are stale (their staging was overwritten)...
        for resp in &out[..4] {
            assert!(batcher.logits_for(resp).is_none(), "stale row served");
        }
        // ...final-batch rows are live and match last_logits
        for resp in &out[4..] {
            let logits = batcher.logits_for(resp).expect("current batch is live");
            assert_eq!(logits, batcher.last_logits().row(resp.row));
            assert_eq!(argmax(logits), resp.prediction);
        }
    }

    #[test]
    fn width_mismatch_is_a_typed_rejection_not_a_panic() {
        // a direct batcher user (no FleetServer validation in front) must
        // not be able to crash the pump loop with a bad request
        let mut rng = Rng::new(8);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 4);
        let mut batcher = MicroBatcher::new(fb, registry);
        let bad = BatchRequest { tenant: 1, id: 1, x: vec![0.0; 4], label: None };
        assert_eq!(
            batcher.try_submit(bad),
            Err(SubmitError::WidthMismatch { expected: 6, got: 4 })
        );
        assert_eq!(batcher.pending(), 0, "rejected request must not be queued");
        // the pump loop stays healthy: a good request still serves
        let good = BatchRequest { tenant: 1, id: 2, x: vec![0.0; 6], label: None };
        assert!(batcher.try_submit(good).is_ok());
        let mut out = Vec::new();
        assert_eq!(batcher.flush(&mut out), 1);
    }

    #[test]
    fn grouped_flush_is_bit_identical_to_the_per_row_reference() {
        // the tentpole invariant, smoke-scale (the seeded multi-tenant
        // sweep lives in tests/kernel_equiv.rs): same requests through
        // flush() and flush_reference() → byte-identical logits
        let mut rng = Rng::new(9);
        let backbone = Arc::new(Mlp::new(&mut rng, cfg()));
        let registry = Arc::new(AdapterRegistry::new());
        for t in 0..3u64 {
            let mut ads: Vec<LoraAdapter> = (0..3)
                .map(|k| LoraAdapter::new(&mut rng, cfg().dims[k], 2, 3))
                .collect();
            for ad in ads.iter_mut() {
                for v in ad.wb.data.iter_mut() {
                    *v = 0.1 * rng.normal();
                }
            }
            registry.publish(t, ads);
        }
        let fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Packed, 8);
        let mut batcher = MicroBatcher::new(fb, Arc::clone(&registry));
        // mixed multiplicities incl. an unpublished tenant (id 7)
        let tenants = [0u64, 1, 0, 2, 7, 1, 0];
        let xs: Vec<Vec<f32>> = (0..tenants.len())
            .map(|_| (0..6).map(|_| rng.normal()).collect())
            .collect();
        let submit_all = |batcher: &mut MicroBatcher| {
            for (i, (&t, x)) in tenants.iter().zip(&xs).enumerate() {
                batcher.submit(BatchRequest { tenant: t, id: i as u64, x: x.clone(), label: None });
            }
        };
        let mut grouped = Vec::new();
        submit_all(&mut batcher);
        assert_eq!(batcher.flush(&mut grouped), tenants.len());
        let grouped_logits: Vec<Vec<f32>> = grouped
            .iter()
            .map(|r| batcher.last_logits().row(r.row).to_vec())
            .collect();
        let mut reference = Vec::new();
        submit_all(&mut batcher);
        assert_eq!(batcher.flush_reference(&mut reference), tenants.len());
        for (g, r) in grouped.iter().zip(&reference) {
            assert_eq!((g.tenant, g.id, g.prediction), (r.tenant, r.id, r.prediction));
            assert_eq!(g.adapter_version, r.adapter_version);
            let want = batcher.last_logits().row(r.row);
            let got = &grouped_logits[g.row];
            assert_eq!(
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "grouped fan-out moved ulps vs the per-row reference"
            );
        }
    }

    #[test]
    fn full_batch_flushes_immediately_regardless_of_deadline() {
        let mut rng = Rng::new(6);
        let backbone = Mlp::new(&mut rng, cfg());
        let registry = Arc::new(AdapterRegistry::new());
        let fb = FrozenBackbone::new(backbone, Backend::Blocked, 4);
        let mut batcher = MicroBatcher::with_deadline(fb, registry, 1_000_000);
        for i in 0..4u64 {
            let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
            batcher.submit(BatchRequest { tenant: i, id: i, x, label: None });
        }
        let mut out = Vec::new();
        assert_eq!(batcher.pump(&mut out), 4, "capacity reached: no waiting");
    }
}
