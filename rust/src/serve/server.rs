//! The fleet front-end: Predict / Feedback / SwapAdapters / Stats over one
//! shared frozen backbone and per-tenant Skip-LoRA adapter sets.
//!
//! Request flow (the admission-control pipeline, DESIGN.md §8):
//!
//! 1. `handle` validates a Predict/Feedback request, charges the tenant's
//!    token bucket (per-tenant rate limiting), and queues it into the
//!    BOUNDED cross-tenant
//!    [`MicroBatcher`](crate::serve::batcher::MicroBatcher) — returning a
//!    ticket, or a typed [`RejectReason`] (`RateLimited` / `QueueFull`)
//!    under overload so the server degrades into back-pressure instead of
//!    unbounded queue growth. `pump` flushes one micro-batch (when full,
//!    or when the oldest request hits the flush deadline) and yields
//!    [`Completion`]s; it also sweeps idle tenants past their TTL out of
//!    the per-tenant state map (their published adapters stay in the
//!    registry — eviction only drops serve-side scratch).
//! 2. Feedback completions drive the per-tenant
//!    [`DriftDetector`](crate::coordinator::core::DriftDetector) +
//!    [`FeedbackBuffer`](crate::coordinator::core::FeedbackBuffer) (the
//!    same control loop as the single-device `DeviceAgent`).
//! 3. On drift, a Skip2-LoRA fine-tune job is launched (inline, or on the
//!    [`WorkerPool`](crate::serve::scheduler::WorkerPool) when
//!    `workers > 0`). The job shares the SAME `Arc<Mlp>` as the batcher —
//!    the split-state layer API makes the backbone `Sync`, so there is no
//!    per-job clone. It trains fresh skip adapters on the tenant's buffer
//!    through the tenant's PERSISTENT `SkipCache`, and publishes the
//!    result to the
//!    [`AdapterRegistry`](crate::serve::registry::AdapterRegistry).
//!
//! Per-tenant caches survive across adaptation rounds because the shared
//! backbone is frozen: a cached activation is valid per (sample, frozen
//! backbone) pair (§4.2), so only buffer slots overwritten since the last
//! round miss (`SkipCache::invalidate`). Tenants are fully isolated — a
//! fine-tune touches one tenant's adapters and nothing shared, and a
//! PANICKING fine-tune job is caught (`catch_unwind`): the failure is
//! counted in [`ServerStats`] and the tenant is restored to a servable
//! state with a fresh cache instead of being stranded.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::cache::SkipCache;
use crate::coordinator::core::{DriftDetector, FeedbackBuffer};
use crate::data::Dataset;
use crate::method::Method;
use crate::model::mlp::AdapterTopology;
use crate::model::{AdapterSet, Mlp};
use crate::nn::lora::LoraAdapter;
use crate::obs::snapshot::{ObsSnapshot, WorkerSnapshot};
use crate::obs::stages::TenantRollups;
use crate::obs::trace::{EventKind, FlightRecorder};
use crate::obs::ObsConfig;
use crate::serve::batcher::{BatchRequest, FrozenBackbone, MicroBatcher, SubmitError, MAX_RANK};
use crate::serve::lanes::{AffinityTracker, LaneFlush, LaneSet};
use crate::serve::metrics::ServeMetrics;
use crate::serve::persist::RegistryCheckpoint;
use crate::serve::registry::{AdapterRegistry, TenantId};
use crate::serve::scheduler::WorkerPool;
use crate::tensor::ops::Backend;
use crate::train::FineTuner;
use crate::util::error::{anyhow, bail, Context, Result as S2lResult};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;

/// Per-tenant token-bucket rate limit, measured in pump ticks (the
/// server's deterministic clock — wall-clock-free so admission decisions
/// are exactly replayable in tests).
///
/// A tenant's bucket starts full at `burst` tokens; each admitted
/// Predict/Feedback request costs one token; `tokens_per_pump` tokens
/// drip back per [`FleetServer::pump`] call (lazily, on the tenant's next
/// request — refill is O(1), never a fleet-wide sweep). A tenant can
/// therefore burst up to `burst` requests instantly but sustain at most
/// `tokens_per_pump` requests per pump.
#[derive(Clone, Copy, Debug)]
pub struct RateLimit {
    /// bucket capacity (max burst size), ≥ 1
    pub burst: f64,
    /// sustained admission rate, tokens per pump tick
    pub tokens_per_pump: f64,
}

/// Server configuration (per-tenant knobs mirror `AgentConfig`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// micro-batch coalescing width (requests per shared forward)
    pub batch_capacity: usize,
    /// flush a partial micro-batch once its oldest request has waited
    /// this many `pump` calls (1 = flush every pump, the greedy policy)
    pub flush_deadline_pumps: u64,
    /// hard bound on the request queue; requests past it get a typed
    /// `Rejected(QueueFull)` instead of growing the queue without limit
    pub queue_bound: usize,
    /// per-tenant token-bucket rate limit; `None` disables rate limiting
    pub rate_limit: Option<RateLimit>,
    /// evict a tenant's serve-side state (SkipCache, drift window,
    /// feedback buffer) after this many pumps of inactivity; `None`
    /// disables eviction. Published adapter versions are NEVER dropped —
    /// an evicted tenant is transparently re-admitted on its next request
    /// and served its latest registry snapshot.
    pub idle_ttl_pumps: Option<u64>,
    /// adapter-registry shard count (power of two; 1 = single lock)
    pub registry_shards: usize,
    /// compute backend for the shared forward and fine-tune jobs
    pub backend: Backend,
    /// per-tenant sliding accuracy window length
    pub window: usize,
    /// fine-tune trigger threshold on window accuracy
    pub accuracy_threshold: f64,
    /// per-tenant fine-tune buffer size |T|
    pub buffer_target: usize,
    /// Skip2-LoRA epochs per fine-tune job
    pub epochs: usize,
    pub lr: f32,
    /// fine-tune mini-batch size
    pub train_batch: usize,
    pub seed: u64,
    /// fine-tune worker threads; 0 = run jobs inline inside `pump`
    pub workers: usize,
    /// serving lanes (DESIGN.md §13): the data plane is sharded into this
    /// many tenant-hash-routed `MicroBatcher` lanes, flushed in parallel
    /// on scoped threads when more than one is due. Must be a power of
    /// two; 1 (the default) is the legacy single-lane path, bit-identical
    /// in behavior AND in its obs document.
    pub lanes: usize,
    /// Fault injection (chaos/testing): the first N fine-tune jobs panic
    /// instead of training, exercising the panic-isolation path. 0 (the
    /// default) disables injection.
    pub inject_adapt_panics: u64,
    /// observability layer (flight recorder, per-stage flush timers,
    /// heavy-hitter rollups — DESIGN.md §11); defaults to fully on
    pub obs: ObsConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            batch_capacity: 32,
            flush_deadline_pumps: crate::serve::batcher::DEFAULT_FLUSH_DEADLINE,
            queue_bound: crate::serve::batcher::DEFAULT_QUEUE_BOUND,
            rate_limit: None,
            idle_ttl_pumps: None,
            registry_shards: crate::serve::registry::DEFAULT_SHARDS,
            // Packed: the frozen backbone's panels are packed once per
            // serving context and reused by every flush
            backend: Backend::default(),
            window: 30,
            accuracy_threshold: 0.75,
            buffer_target: 60,
            epochs: 40,
            lr: 0.05,
            train_batch: 20,
            seed: 7,
            workers: 0,
            lanes: 1,
            inject_adapt_panics: 0,
            obs: ObsConfig::default(),
        }
    }
}

/// Front-end requests.
#[derive(Clone, Debug)]
pub enum Request {
    /// unlabelled sample: predict
    Predict(Vec<f32>),
    /// labelled sample: predict, score, buffer for adaptation
    Feedback(Vec<f32>, usize),
    /// externally trained adapters (e.g. migrated from another node)
    SwapAdapters(Vec<LoraAdapter>),
    /// checkpoint every tenant's published adapters + versions to disk
    /// (crash-safe: see [`FleetServer::persist_to`]); the tenant id on
    /// `handle` is ignored — this is a fleet-wide operation
    SaveState(PathBuf),
    /// install a checkpoint written by `SaveState` (see
    /// [`FleetServer::restore_from`]); fleet-wide, tenant id ignored
    RestoreState(PathBuf),
    Stats,
    /// read-only observability snapshot (`skip2lora/obs/v1`: mergeable
    /// metrics, per-stage flush attribution, flight-recorder tail —
    /// DESIGN.md §11); fleet-wide, tenant id ignored
    Observe,
}

/// Why a request was turned away — typed so clients can react correctly
/// (retry later vs fix the request) and so every rejection path is
/// countable in [`ServerStats`].
#[derive(Clone, Debug, PartialEq)]
pub enum RejectReason {
    /// the bounded request queue is at its limit — back off and retry
    QueueFull { bound: usize },
    /// the tenant's token bucket is empty — retry after the bucket drips
    RateLimited,
    /// the request itself is invalid (shape / label / adapter mismatch)
    Malformed(String),
    /// a SaveState/RestoreState/migration operation failed (I/O error,
    /// torn or incompatible checkpoint) — the serving state is untouched
    PersistFailed(String),
    /// the server is draining ([`FleetServer::drain`]): admissions are
    /// closed so the queue can only shrink; route to another node (the
    /// fleet router does) or retry after `resume_admissions`
    Draining,
}

/// Result of a [`FleetServer::drain`]: what was in flight when admissions
/// closed, and every completion the drain flushed out — so callers can
/// balance the books (nothing accepted is ever lost across a drain).
#[derive(Debug, Default)]
pub struct DrainReport {
    /// requests still queued when the drain began — all of them appear in
    /// `completions`
    pub queued_at_start: usize,
    /// fine-tune jobs in flight when the drain began, all joined before
    /// the drain returned
    pub finetunes_joined: usize,
    /// every request the drain served while emptying the queue
    pub completions: Vec<Completion>,
}

/// Result of a successful [`FleetServer::persist_to`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PersistReport {
    /// tenants captured in the checkpoint
    pub tenants: usize,
    /// serialized checkpoint size on disk
    pub bytes: usize,
}

/// Result of a successful [`FleetServer::restore_from`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RestoreReport {
    /// tenants carried by the checkpoint
    pub tenants: usize,
    /// tenants actually installed (the rest were already live at an
    /// equal-or-newer version — restore never rolls a tenant backwards)
    pub installed: usize,
    /// highest per-tenant version in the checkpoint
    pub max_version: u64,
}

/// Immediate response to `handle` (Predict/Feedback resolve later via
/// [`FleetServer::pump`]).
#[derive(Debug)]
pub enum Response {
    /// queued into the micro-batch; the ticket reappears in a Completion
    Queued { ticket: u64 },
    Swapped { version: u64 },
    /// fleet state checkpointed to disk
    Persisted(PersistReport),
    /// fleet state installed from a checkpoint
    Restored(RestoreReport),
    Rejected(RejectReason),
    Stats(Box<ServerStats>),
    /// the full observability snapshot (boxed — it carries histograms,
    /// the recorder tail and the rollup table)
    Observed(Box<ObsSnapshot>),
}

/// A served Predict/Feedback request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub tenant: TenantId,
    pub ticket: u64,
    pub prediction: usize,
    pub label: Option<usize>,
    pub correct: Option<bool>,
    pub adapter_version: u64,
}

/// Aggregate server statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub tenants: usize,
    pub publishes: u64,
    pub adaptations: u64,
    /// fine-tune jobs that panicked and were isolated (tenant restored)
    pub finetune_panics: u64,
    pub batches: u64,
    pub rows: u64,
    pub rows_per_batch: f64,
    pub adapter_bytes: usize,
    /// requests rejected because the bounded queue was at its limit
    pub queue_rejections: u64,
    /// requests rejected by the per-tenant token bucket
    pub rate_limited: u64,
    /// idle tenants whose serve-side state was evicted (TTL policy)
    pub evictions: u64,
    /// requests currently waiting in the (bounded) queue
    pub queued: usize,
    /// the queue's configured bound — `queued` never exceeds this
    pub queue_bound: usize,
    /// adapter-registry shard count
    pub registry_shards: usize,
    /// fleet checkpoints written (`persist_to` / `SaveState`)
    pub persists: u64,
    /// fleet checkpoints installed (`restore_from` / `RestoreState`)
    pub restores: u64,
}

struct TenantState {
    detector: DriftDetector,
    buffer: FeedbackBuffer,
    /// `None` while a fine-tune job owns the cache (buffer is frozen too)
    cache: Option<SkipCache>,
    adaptations: u64,
    feedbacks: u64,
    /// training-set accuracy reported by the most recent fine-tune
    last_adapt_accuracy: f64,
    /// pump tick of the tenant's most recent request/feedback — drives
    /// the idle-TTL eviction sweep
    last_active_tick: u64,
    /// token-bucket fill (only meaningful when rate limiting is on)
    bucket_tokens: f64,
    /// pump tick of the last lazy bucket refill
    bucket_tick: u64,
    /// the worker whose cache last ran this tenant's fine-tune — the
    /// affinity pin for the next job (`serve::lanes::AffinityTracker`)
    pinned_worker: Option<usize>,
}

impl TenantState {
    fn new(cfg: &ServeConfig, tick: u64) -> Self {
        Self {
            detector: DriftDetector::new(cfg.window, cfg.accuracy_threshold),
            buffer: FeedbackBuffer::new(cfg.buffer_target),
            cache: Some(SkipCache::new(cfg.buffer_target)),
            adaptations: 0,
            feedbacks: 0,
            last_adapt_accuracy: 0.0,
            last_active_tick: tick,
            // a fresh (or re-admitted) tenant starts with a full bucket
            bucket_tokens: cfg.rate_limit.map_or(0.0, |rl| rl.burst),
            bucket_tick: tick,
            pinned_worker: None,
        }
    }
}

/// Result of one fine-tune job, sent back over the result channel.
struct AdaptResult {
    tenant: TenantId,
    /// the tenant's cache, returned after the job (warm for next round)
    cache: SkipCache,
    /// training-set accuracy after the fine-tune
    acc_after: f64,
    train_secs: f64,
    cache_hits: u64,
    cache_misses: u64,
    /// per-stage wall-clock over the whole job (the paper's Tables 6/7
    /// taxonomy), extracted from the job's `PhaseTimer`
    forward_ns: u64,
    backward_ns: u64,
    update_ns: u64,
    cache_ns: u64,
}

/// What a fine-tune job reports back: success, or an isolated panic.
enum AdaptMsg {
    Done(Box<AdaptResult>),
    /// the job panicked; its cache was lost in the unwind — the server
    /// restores the tenant with a fresh one
    Panicked { tenant: TenantId },
}

pub struct FleetServer {
    cfg: ServeConfig,
    /// THE shared frozen backbone: the same `Arc` is held by the batcher
    /// and handed (by pointer) to every fine-tune job. The split-state
    /// layer API makes `Mlp: Sync`, so nobody ever clones the weights.
    backbone: Arc<Mlp>,
    pub registry: Arc<AdapterRegistry>,
    /// the data plane: N tenant-hash-routed `MicroBatcher` lanes (1 =
    /// the legacy single-lane path, exactly)
    lanes: LaneSet,
    tenants: HashMap<TenantId, TenantState>,
    pool: Option<WorkerPool>,
    /// fine-tune placement pinning (Some iff `pool` is Some)
    affinity: Option<AffinityTracker>,
    results_tx: mpsc::Sender<AdaptMsg>,
    results_rx: mpsc::Receiver<AdaptMsg>,
    pub metrics: ServeMetrics,
    next_ticket: u64,
    /// the server's deterministic clock: increments once per `pump`.
    /// Token-bucket refills and the idle-TTL sweep both run on it, so
    /// admission/eviction behavior is exactly replayable in tests.
    pump_tick: u64,
    /// flight recorder: preallocated ring of typed events, dual-stamped
    /// on (pump_tick, monotonic ns); zero-alloc on the hot path
    recorder: FlightRecorder,
    /// bounded heavy-hitter per-tenant rollups (top-K table)
    rollups: TenantRollups,
    /// admissions closed ([`FleetServer::drain`]): Predict/Feedback get a
    /// typed `Rejected(Draining)` until `resume_admissions`
    draining: bool,
    /// reusable per-pump flush log (which lanes flushed, rows, ns) — kept
    /// warm so `pump` does not allocate it every tick
    flush_log: Vec<LaneFlush>,
}

impl FleetServer {
    /// Deploy a pre-trained frozen backbone (adapters are per-tenant and
    /// live in the registry). Accepts an owned `Mlp` or an existing
    /// `Arc<Mlp>`.
    pub fn new(backbone: impl Into<Arc<Mlp>>, cfg: ServeConfig) -> Self {
        if let Some(rl) = cfg.rate_limit {
            // a burst below one token would silently reject EVERY request
            // forever (the refill caps at `burst`); catch it at deploy
            // time like the batcher's own limit asserts
            assert!(
                rl.burst >= 1.0 && rl.burst.is_finite(),
                "rate_limit.burst must be >= 1 (got {})",
                rl.burst
            );
            assert!(
                rl.tokens_per_pump >= 0.0 && rl.tokens_per_pump.is_finite(),
                "rate_limit.tokens_per_pump must be finite and >= 0 (got {})",
                rl.tokens_per_pump
            );
        }
        assert!(
            cfg.lanes >= 1 && cfg.lanes.is_power_of_two(),
            "lanes must be a power of two >= 1 (got {})",
            cfg.lanes
        );
        let backbone: Arc<Mlp> = backbone.into();
        let registry = Arc::new(AdapterRegistry::with_shards(cfg.registry_shards));
        let lanes = LaneSet::new(cfg.lanes, cfg.obs.trace_capacity, cfg.obs.trace, |_| {
            let frozen =
                FrozenBackbone::new(Arc::clone(&backbone), cfg.backend, cfg.batch_capacity);
            let mut batcher = MicroBatcher::with_limits(
                frozen,
                Arc::clone(&registry),
                cfg.flush_deadline_pumps,
                cfg.queue_bound,
            );
            batcher.set_stage_timing(cfg.obs.stage_timers);
            batcher
        });
        let recorder = FlightRecorder::new(cfg.obs.trace_capacity, cfg.obs.trace);
        let rollups = TenantRollups::new(cfg.obs.top_tenants);
        let pool = (cfg.workers > 0).then(|| WorkerPool::new(cfg.workers));
        let affinity = (cfg.workers > 0).then(|| AffinityTracker::new(cfg.workers));
        let (results_tx, results_rx) = mpsc::channel();
        Self {
            cfg,
            backbone,
            registry,
            lanes,
            tenants: HashMap::new(),
            pool,
            affinity,
            results_tx,
            results_rx,
            metrics: ServeMetrics::new(),
            next_ticket: 0,
            pump_tick: 0,
            recorder,
            rollups,
            draining: false,
            flush_log: Vec::new(),
        }
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The shared backbone handle (tests assert pointer identity with the
    /// batcher and fine-tune jobs).
    pub fn shared_backbone(&self) -> &Arc<Mlp> {
        &self.backbone
    }

    pub fn n_in(&self) -> usize {
        self.lanes.n_in()
    }

    pub fn n_classes(&self) -> usize {
        self.lanes.n_out()
    }

    /// Handle one front-end request. Predict/Feedback run the admission
    /// pipeline: validate → per-tenant token bucket → bounded queue; each
    /// stage rejects with its own typed [`RejectReason`].
    pub fn handle(&mut self, tenant: TenantId, req: Request) -> Response {
        match req {
            Request::Predict(x) => {
                if self.draining {
                    return Response::Rejected(RejectReason::Draining);
                }
                if x.len() != self.n_in() {
                    return Response::Rejected(RejectReason::Malformed(format!(
                        "expected {} features, got {}",
                        self.n_in(),
                        x.len()
                    )));
                }
                match self.admit_and_enqueue(tenant, x, None) {
                    Ok(ticket) => {
                        self.metrics.predicts += 1;
                        Response::Queued { ticket }
                    }
                    Err(reason) => Response::Rejected(reason),
                }
            }
            Request::Feedback(x, label) => {
                if self.draining {
                    return Response::Rejected(RejectReason::Draining);
                }
                if x.len() != self.n_in() {
                    return Response::Rejected(RejectReason::Malformed(format!(
                        "expected {} features, got {}",
                        self.n_in(),
                        x.len()
                    )));
                }
                if label >= self.n_classes() {
                    return Response::Rejected(RejectReason::Malformed(format!(
                        "label {label} out of range (n_classes {})",
                        self.n_classes()
                    )));
                }
                match self.admit_and_enqueue(tenant, x, Some(label)) {
                    Ok(ticket) => {
                        self.metrics.feedbacks += 1;
                        Response::Queued { ticket }
                    }
                    Err(reason) => Response::Rejected(reason),
                }
            }
            Request::SwapAdapters(adapters) => match self.validate_adapters(&adapters) {
                Ok(()) => {
                    let tick = self.pump_tick;
                    let st = self
                        .tenants
                        .entry(tenant)
                        .or_insert_with(|| TenantState::new(&self.cfg, tick));
                    st.last_active_tick = tick;
                    // adapters are weights-only by construction — nothing
                    // to compact before the registry snapshot
                    let version = self.registry.publish(tenant, adapters);
                    self.metrics.swaps += 1;
                    Response::Swapped { version }
                }
                Err(msg) => Response::Rejected(RejectReason::Malformed(msg)),
            },
            Request::SaveState(path) => match self.persist_to(&path) {
                Ok(report) => Response::Persisted(report),
                Err(e) => Response::Rejected(RejectReason::PersistFailed(e.to_string())),
            },
            Request::RestoreState(path) => match self.restore_from(&path) {
                Ok(report) => Response::Restored(report),
                Err(e) => Response::Rejected(RejectReason::PersistFailed(e.to_string())),
            },
            Request::Stats => Response::Stats(Box::new(self.stats())),
            Request::Observe => Response::Observed(Box::new(self.obs_snapshot())),
        }
    }

    /// Checkpoint the fleet's durable state — every tenant's published
    /// adapters + version, plus the global version counter — to `path`,
    /// atomically (tmp + fsync + rename: a crash mid-save leaves the
    /// previous checkpoint intact, never a torn file). Serve-side scratch
    /// (SkipCaches, drift windows, buckets) is deliberately NOT persisted:
    /// it is cheap to rebuild and exactly what TTL eviction already drops.
    pub fn persist_to(&mut self, path: &Path) -> S2lResult<PersistReport> {
        let ck = RegistryCheckpoint::capture(&self.registry);
        // unreachable through this server's own publishes (every path
        // shape-checks against the one backbone), but a checkpoint that
        // could not be loaded back must never reach disk
        ck.validate()?;
        let bytes = ck.to_bytes();
        crate::model::io::atomic_write(path, &bytes)
            .with_context(|| format!("persist fleet state to {}", path.display()))?;
        self.metrics.persists += 1;
        self.recorder.record(EventKind::Persisted { tenants: ck.tenants.len() as u32 });
        Ok(PersistReport { tenants: ck.tenants.len(), bytes: bytes.len() })
    }

    /// Install the checkpoint at `path`: every tenant is validated
    /// against THIS backbone (the same shape/rank checks as
    /// `SwapAdapters`) before anything is touched — a checkpoint from an
    /// incompatible deployment is rejected whole. Each valid tenant is
    /// re-published at a version ≥ its persisted one (exact when the
    /// live registry has nothing newer), and post-restore publishes
    /// outrank everything persisted, so per-tenant version monotonicity
    /// survives the crash/restore boundary.
    pub fn restore_from(&mut self, path: &Path) -> S2lResult<RestoreReport> {
        let ck = RegistryCheckpoint::load(path)?;
        for rec in &ck.tenants {
            self.validate_adapters(rec.adapters())
                .map_err(|msg| anyhow!("checkpoint tenant {}: {msg}", rec.tenant()))?;
        }
        let installed = ck.restore_into(&self.registry);
        self.metrics.restores += 1;
        self.metrics.tenants_restored += installed as u64;
        self.recorder.record(EventKind::Restored { tenants: installed as u32 });
        Ok(RestoreReport {
            tenants: ck.tenants.len(),
            installed,
            max_version: ck.tenants.iter().map(|r| r.version()).max().unwrap_or(0),
        })
    }

    /// Export one tenant's published adapters as a validated migration
    /// payload (`.s2l` bytes) for another node's [`FleetServer::import_tenant`].
    pub fn export_tenant(&mut self, tenant: TenantId) -> S2lResult<Vec<u8>> {
        let ck = RegistryCheckpoint::capture_tenant(&self.registry, tenant)
            .with_context(|| format!("tenant {tenant} has no published adapters to export"))?;
        self.metrics.exports += 1;
        Ok(ck.to_bytes())
    }

    /// Install a migrated tenant from `export_tenant` bytes. The payload
    /// runs the SAME validation as a `SwapAdapters` request (layer count,
    /// shapes, serving rank limit) and is then published at a version
    /// allocated by THIS node — migration is an ordinary publish here,
    /// not a cross-node version splice, so local monotonicity is trivially
    /// preserved. Returns the tenant id and its new local version.
    pub fn import_tenant(&mut self, bytes: &[u8]) -> S2lResult<(TenantId, u64)> {
        let ck = RegistryCheckpoint::from_bytes(bytes)?;
        if ck.tenants.len() != 1 {
            bail!(
                "migration payload must hold exactly one tenant, got {}",
                ck.tenants.len()
            );
        }
        let rec = &ck.tenants[0];
        self.validate_adapters(rec.adapters())
            .map_err(|msg| anyhow!("imported tenant {}: {msg}", rec.tenant()))?;
        let tick = self.pump_tick;
        let st = self
            .tenants
            .entry(rec.tenant())
            .or_insert_with(|| TenantState::new(&self.cfg, tick));
        st.last_active_tick = tick;
        let version = self.registry.publish(rec.tenant(), rec.adapters().to_vec());
        self.metrics.imports += 1;
        Ok((rec.tenant(), version))
    }

    fn validate_adapters(&self, adapters: &[LoraAdapter]) -> Result<(), String> {
        let dims = &self.backbone.config.dims;
        let n = self.backbone.n_layers();
        if adapters.len() != n {
            return Err(format!("expected {n} skip adapters, got {}", adapters.len()));
        }
        for (k, ad) in adapters.iter().enumerate() {
            if ad.n_in() != dims[k] || ad.n_out() != dims[n] {
                return Err(format!(
                    "adapter {k}: shape {}x{}, want {}x{}",
                    ad.n_in(),
                    ad.n_out(),
                    dims[k],
                    dims[n]
                ));
            }
            if ad.rank() > MAX_RANK {
                return Err(format!(
                    "adapter {k}: rank {} exceeds the serving limit {MAX_RANK}",
                    ad.rank()
                ));
            }
        }
        Ok(())
    }

    /// The admission pipeline for one Predict/Feedback request: create or
    /// re-admit the tenant's state, charge its token bucket, then try the
    /// bounded queue. Every rejection is counted in [`ServeMetrics`].
    fn admit_and_enqueue(
        &mut self,
        tenant: TenantId,
        x: Vec<f32>,
        label: Option<usize>,
    ) -> Result<u64, RejectReason> {
        let tick = self.pump_tick;
        let rate_limit = self.cfg.rate_limit;
        let st = self
            .tenants
            .entry(tenant)
            .or_insert_with(|| TenantState::new(&self.cfg, tick));
        st.last_active_tick = tick;
        if let Some(rl) = rate_limit {
            // lazy refill: tokens drip per pump tick, capped at the burst
            let elapsed = tick.saturating_sub(st.bucket_tick) as f64;
            st.bucket_tokens = (st.bucket_tokens + elapsed * rl.tokens_per_pump).min(rl.burst);
            st.bucket_tick = tick;
            if st.bucket_tokens < 1.0 {
                self.metrics.rate_limited += 1;
                return Err(RejectReason::RateLimited);
            }
            st.bucket_tokens -= 1.0;
        }
        // past the bucket: the request is ADMITTED (the bounded queue may
        // still reject it, which the trace then shows as admitted-but-
        // never-queued — exactly the back-pressure signature)
        self.recorder.record(EventKind::Admitted { tenant });
        let id = self.next_ticket + 1;
        match self.lanes.try_submit(BatchRequest { tenant, id, x, label }) {
            Ok(()) => {
                self.next_ticket = id;
                self.recorder.record(EventKind::Queued { tenant, ticket: id });
                self.rollups.bump_request(tenant);
                Ok(id)
            }
            Err(SubmitError::QueueFull { bound }) => {
                self.metrics.queue_rejections += 1;
                Err(RejectReason::QueueFull { bound })
            }
            // unreachable through `handle` (it width-checks first), but a
            // batcher-level rejection must still map to a typed response
            Err(SubmitError::WidthMismatch { expected, got }) => Err(RejectReason::Malformed(
                format!("expected {expected} features, got {got}"),
            )),
        }
    }

    /// Requests queued but not yet served (all lanes).
    pub fn queued(&self) -> usize {
        self.lanes.pending()
    }

    /// Drain finished fine-tune jobs, sweep idle tenants past their TTL,
    /// pump the micro-batcher once (it flushes when full or past the
    /// deadline), and process feedback (drift detection + adaptation
    /// launch). Returns the served requests.
    pub fn pump(&mut self) -> Vec<Completion> {
        self.pump_tick += 1;
        self.metrics.pump_ticks += 1;
        self.recorder.set_tick(self.pump_tick);
        self.lanes.set_tick(self.pump_tick);
        self.drain_adapt_results();
        self.evict_idle();
        let mut responses = Vec::new();
        let t0 = Instant::now();
        // one pump over every lane: the single-lane path traces its flush
        // events straight into the server's recorder (disjoint-field
        // borrow, byte-identical to the pre-lane behavior); multi-lane
        // sets trace per lane and merge at snapshot time
        let mut flush_log = std::mem::take(&mut self.flush_log);
        self.lanes
            .pump(&mut responses, &mut flush_log, Some(&mut self.recorder));
        for f in &flush_log {
            // with stage timing on, record each flush's OWN measured span —
            // the same total the per-stage timers decompose, so stage sums
            // reconcile against this histogram (tests/obs_subsystem.rs
            // holds them within 5%); with timing off, fall back to the
            // pump-side wall clock
            let flush_ns = f.ns.unwrap_or_else(|| t0.elapsed().as_nanos() as u64);
            self.metrics.batch_forward.record_ns(flush_ns);
            self.metrics.batches += 1;
            self.metrics.batched_rows += f.rows as u64;
        }
        self.flush_log = flush_log;
        let mut out = Vec::with_capacity(responses.len());
        for resp in responses {
            let correct = resp.label.map(|l| resp.prediction == l);
            out.push(Completion {
                tenant: resp.tenant,
                ticket: resp.id,
                prediction: resp.prediction,
                label: resp.label,
                correct,
                adapter_version: resp.adapter_version,
            });
            if let Some(label) = resp.label {
                // feedback responses carry the request features back by
                // move (the only path that needs them — predicts don't
                // pay the echo)
                let x = resp.x.expect("feedback response echoes x");
                self.apply_feedback(resp.tenant, x, label, correct.unwrap());
            }
        }
        out
    }

    /// Pump until the request queue is empty (the flush deadline
    /// guarantees progress even for a lone trailing request).
    pub fn pump_until_drained(&mut self) -> Vec<Completion> {
        let mut all = Vec::new();
        while self.queued() > 0 {
            all.extend(self.pump());
        }
        self.drain_adapt_results();
        all
    }

    /// TTL eviction: drop the serve-side state (SkipCache, drift window,
    /// feedback buffer, token bucket) of tenants idle past
    /// `idle_ttl_pumps`. Published adapter versions live in the registry
    /// and are untouched — the next request from an evicted tenant
    /// re-admits it transparently and is served its latest snapshot. A
    /// tenant with a fine-tune job in flight is never evicted (its cache
    /// must come home first). The sweep is amortized to every `ttl/4`
    /// pumps, so a tenant is evicted at most ~1.25×TTL after going idle.
    fn evict_idle(&mut self) {
        let Some(ttl) = self.cfg.idle_ttl_pumps else {
            return;
        };
        let sweep_every = (ttl / 4).max(1);
        if self.pump_tick % sweep_every != 0 {
            return;
        }
        let tick = self.pump_tick;
        let before = self.tenants.len();
        // borrow split: `retain` holds the tenants map, the closure takes
        // only the recorder — disjoint fields of self
        let recorder = &mut self.recorder;
        self.tenants.retain(|&tenant, st| {
            let keep = st.cache.is_none() || tick.saturating_sub(st.last_active_tick) < ttl;
            if !keep {
                recorder.record(EventKind::Evicted { tenant });
            }
            keep
        });
        self.metrics.evictions += (before - self.tenants.len()) as u64;
    }

    fn apply_feedback(&mut self, tenant: TenantId, x: Vec<f32>, label: usize, correct: bool) {
        let tick = self.pump_tick;
        // the tenant can have been evicted between enqueue and flush (a
        // TTL shorter than the queue dwell): re-admit with fresh state
        // rather than dropping the feedback
        let st = self
            .tenants
            .entry(tenant)
            .or_insert_with(|| TenantState::new(&self.cfg, tick));
        st.last_active_tick = tick;
        st.feedbacks += 1;
        st.detector.push(correct);
        if let Some(cache) = st.cache.as_mut() {
            // buffer mutable only while no job owns the cache; overwriting
            // slot i invalidates C_skip[i] (§4.2: entry is per sample)
            let slot = st.buffer.push(x, label);
            cache.invalidate(slot);
        }
        if st.detector.drifted() && st.buffer.is_full() && st.cache.is_some() {
            self.launch_adapt(tenant);
        }
    }

    fn launch_adapt(&mut self, tenant: TenantId) {
        let n_classes = self.n_classes();
        let st = self.tenants.get_mut(&tenant).expect("tenant exists");
        let data = st.buffer.to_dataset(n_classes);
        let cache = st.cache.take().expect("cache present when launching");
        st.detector.reset();
        let round = st.adaptations;
        st.adaptations += 1;
        let pinned = st.pinned_worker;
        // fault injection: the first `inject_adapt_panics` jobs fail
        let inject_panic = self.metrics.adaptations < self.cfg.inject_adapt_panics;
        self.metrics.adaptations += 1;
        self.recorder.record(EventKind::FinetuneStart { tenant });

        // pointer clone of the SHARED backbone — never a weight copy;
        // Skip2-LoRA is a frozen-backbone method, so the job only ever
        // reads through the Arc
        let backbone = Arc::clone(&self.backbone);
        let registry = Arc::clone(&self.registry);
        let tx = self.results_tx.clone();
        let seed = self.cfg.seed ^ tenant.rotate_left(17) ^ round;
        let (epochs, lr, train_batch, backend) =
            (self.cfg.epochs, self.cfg.lr, self.cfg.train_batch, self.cfg.backend);
        let job = move || {
            // isolate panics: a crashing job must not strand the tenant
            // with `cache = None` (or kill a pool worker)
            let result = catch_unwind(AssertUnwindSafe(|| {
                if inject_panic {
                    panic!("injected fine-tune fault (ServeConfig::inject_adapt_panics)");
                }
                run_finetune(
                    backbone, &registry, tenant, &data, cache, epochs, lr, train_batch,
                    backend, seed,
                )
            }));
            let msg = match result {
                Ok(res) => AdaptMsg::Done(Box::new(res)),
                Err(_) => AdaptMsg::Panicked { tenant },
            };
            // receiver lives as long as the server; a send error just
            // means the server was dropped mid-job
            let _ = tx.send(msg);
        };
        match &self.pool {
            Some(pool) => {
                // cache-affinity placement (DESIGN.md §13): send the job
                // back to the worker whose cache last touched this
                // tenant's adapters; idle siblings may still steal it, so
                // this is a placement hint and hits/misses count intent.
                // NOTE: only field-disjoint accesses below — `pool`
                // borrows self.pool for the whole arm.
                let tracker = self
                    .affinity
                    .as_mut()
                    .expect("affinity tracker exists whenever a pool does");
                let (worker, hit) = tracker.place(tenant, pinned);
                if hit {
                    self.metrics.affinity_hits += 1;
                } else {
                    self.metrics.affinity_misses += 1;
                }
                if let Some(st) = self.tenants.get_mut(&tenant) {
                    st.pinned_worker = Some(worker);
                }
                pool.submit_to(worker, job);
            }
            None => {
                job();
                self.drain_adapt_results();
            }
        }
    }

    fn drain_adapt_results(&mut self) {
        while let Ok(msg) = self.results_rx.try_recv() {
            match msg {
                AdaptMsg::Done(res) => {
                    self.metrics.finetune.record_secs(res.train_secs);
                    self.metrics.finetune_cache_hits += res.cache_hits;
                    self.metrics.finetune_cache_misses += res.cache_misses;
                    // paper Tables 6/7: accumulate the job's stage split
                    self.metrics.finetune_forward_ns += res.forward_ns;
                    self.metrics.finetune_backward_ns += res.backward_ns;
                    self.metrics.finetune_update_ns += res.update_ns;
                    self.metrics.finetune_cache_ns += res.cache_ns;
                    let job_ns = (res.train_secs.max(0.0) * 1e9) as u64;
                    self.recorder
                        .record(EventKind::FinetuneEnd { tenant: res.tenant, ns: job_ns });
                    if res.cache_hits > 0 {
                        self.recorder.record(EventKind::CacheHit {
                            tenant: res.tenant,
                            count: res.cache_hits.min(u32::MAX as u64) as u32,
                        });
                    }
                    if res.cache_misses > 0 {
                        self.recorder.record(EventKind::CacheMiss {
                            tenant: res.tenant,
                            count: res.cache_misses.min(u32::MAX as u64) as u32,
                        });
                    }
                    self.rollups.record_finetune(
                        res.tenant,
                        job_ns,
                        res.cache_hits,
                        res.cache_misses,
                    );
                    if let Some(st) = self.tenants.get_mut(&res.tenant) {
                        st.cache = Some(res.cache);
                        st.last_adapt_accuracy = res.acc_after;
                        // outcomes recorded while the job ran were scored
                        // against the OLD adapters; reset so the window
                        // measures the new ones instead of instantly
                        // re-triggering a redundant job
                        st.detector.reset();
                    }
                }
                AdaptMsg::Panicked { tenant } => {
                    // the cache moved into the job and was dropped by the
                    // unwind: restore the tenant to a servable state with
                    // a fresh (cold) cache and count the failure
                    self.metrics.finetune_panics += 1;
                    if let Some(st) = self.tenants.get_mut(&tenant) {
                        st.cache = Some(SkipCache::new(self.cfg.buffer_target));
                        st.detector.reset();
                    }
                }
            }
        }
    }

    /// Is a fine-tune job in flight for this tenant?
    pub fn is_adapting(&self, tenant: TenantId) -> bool {
        self.tenants
            .get(&tenant)
            .is_some_and(|st| st.cache.is_none())
    }

    pub fn any_adapting(&self) -> bool {
        self.tenants.values().any(|st| st.cache.is_none())
    }

    /// Block (pumping) until every queued request is served and every
    /// fine-tune job has landed.
    pub fn quiesce(&mut self) {
        loop {
            self.pump_until_drained();
            if !self.any_adapting() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_micros(500));
        }
    }

    /// Graceful drain: close admissions (new Predict/Feedback get a typed
    /// `Rejected(Draining)`), flush EVERY queued request out of the
    /// batcher, and join every in-flight fine-tune job. Nothing accepted
    /// before the drain is lost — the flushed completions come back in
    /// the report so callers can balance the books. The server stays
    /// fully alive afterwards (admin ops, export/import, Observe all
    /// work); `resume_admissions` re-opens the data plane. Used by both
    /// the network edge (node decommission) and the migration path
    /// (drain-before-export, so a tenant can never lose a queued request
    /// to a mid-flight move).
    pub fn drain(&mut self) -> DrainReport {
        self.draining = true;
        let queued_at_start = self.queued();
        let finetunes_joined = self.tenants.values().filter(|st| st.cache.is_none()).count();
        let completions = self.pump_until_drained();
        // join fine-tunes launched before OR during the flush (feedback
        // completions can still trigger adaptation on the way out)
        self.quiesce();
        DrainReport { queued_at_start, finetunes_joined, completions }
    }

    /// Re-open admissions after a [`FleetServer::drain`] — the migration
    /// path drains, exports the moving tenant, then resumes the (still
    /// running) source node for its remaining tenants.
    pub fn resume_admissions(&mut self) {
        self.draining = false;
    }

    /// Is the server currently refusing Predict/Feedback admissions?
    pub fn is_draining(&self) -> bool {
        self.draining
    }

    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    pub fn tenant_window_accuracy(&self, tenant: TenantId) -> Option<f64> {
        self.tenants.get(&tenant).map(|st| st.detector.accuracy())
    }

    pub fn tenant_adaptations(&self, tenant: TenantId) -> u64 {
        self.tenants.get(&tenant).map_or(0, |st| st.adaptations)
    }

    /// Labelled samples this tenant has fed back so far.
    pub fn tenant_feedbacks(&self, tenant: TenantId) -> u64 {
        self.tenants.get(&tenant).map_or(0, |st| st.feedbacks)
    }

    /// Training-set accuracy reported by the tenant's most recent
    /// fine-tune (`None` if it never adapted).
    pub fn tenant_last_adapt_accuracy(&self, tenant: TenantId) -> Option<f64> {
        self.tenants
            .get(&tenant)
            .filter(|st| st.adaptations > 0 && st.cache.is_some())
            .map(|st| st.last_adapt_accuracy)
    }

    pub fn tenant_version(&self, tenant: TenantId) -> u64 {
        self.registry.version(tenant)
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            tenants: self.tenants.len(),
            publishes: self.registry.publishes(),
            adaptations: self.metrics.adaptations,
            finetune_panics: self.metrics.finetune_panics,
            batches: self.lanes.total_batches(),
            rows: self.lanes.total_rows(),
            rows_per_batch: self.metrics.rows_per_batch(),
            adapter_bytes: self.registry.total_adapter_bytes(),
            queue_rejections: self.metrics.queue_rejections,
            rate_limited: self.metrics.rate_limited,
            evictions: self.metrics.evictions,
            queued: self.lanes.pending(),
            queue_bound: self.lanes.queue_bound_total(),
            registry_shards: self.registry.shard_count(),
            persists: self.metrics.persists,
            restores: self.metrics.restores,
        }
    }

    /// Assemble the full observability snapshot (schema
    /// `skip2lora/obs/v1`): mergeable `ServeMetrics` with raw histogram
    /// buckets, per-stage flush attribution, the paper-style fine-tune
    /// stage split, the flight-recorder summary, the bounded heavy-hitter
    /// tenant table, per-shard registry stats and per-worker queue depths.
    /// Cold path: clones and allocates freely; the hot path only ever
    /// wrote into the fixed-size structures this copies from.
    pub fn obs_snapshot(&self) -> ObsSnapshot {
        // Multi-lane: stages fold across lanes under the PR-6 merge laws
        // and the per-lane flight recorders merge into the control
        // recorder's summary; single-lane is byte-identical to the
        // pre-lane document (no `lanes` key, control recorder only).
        let mut trace = self.recorder.summary();
        if self.lanes.n_lanes() > 1 {
            self.lanes.merge_trace_into(&mut trace);
        }
        ObsSnapshot {
            pump_ticks: self.pump_tick,
            tenants_live: self.tenants.len(),
            queued: self.lanes.pending(),
            metrics: self.metrics.clone(),
            flush_stages: self.lanes.stages_merged(),
            trace,
            tenants: self.rollups.top(),
            shards: self.registry.shard_stats(),
            workers: self.pool.as_ref().map(|p| WorkerSnapshot {
                stats: p.stats(),
                queue_depths: p.queue_depths(),
            }),
            lanes: if self.lanes.n_lanes() > 1 {
                self.lanes.snapshots()
            } else {
                Vec::new()
            },
        }
    }

    /// Direct read access to the flight recorder (tests, debuggers).
    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    /// Quiesce and shut the worker pool down.
    pub fn shutdown(mut self) -> ServerStats {
        self.quiesce();
        if let Some(pool) = self.pool.take() {
            pool.shutdown();
        }
        self.stats()
    }
}

/// One Skip2-LoRA fine-tune job: fresh skip adapters trained against the
/// SHARED frozen backbone (no clone — the job reads the same `Arc<Mlp>`
/// the batcher serves from) on the tenant's buffer through its persistent
/// cache, published to the registry on completion.
#[allow(clippy::too_many_arguments)]
fn run_finetune(
    model: Arc<Mlp>,
    registry: &Arc<AdapterRegistry>,
    tenant: TenantId,
    data: &Dataset,
    mut cache: SkipCache,
    epochs: usize,
    lr: f32,
    train_batch: usize,
    backend: Backend,
    seed: u64,
) -> AdaptResult {
    let t0 = Instant::now();
    let hits0 = cache.stats().hits;
    let misses0 = cache.stats().misses;
    let mut rng = Rng::new(seed);
    // fresh adapters per round: LoRA portability means stale adapters are
    // discarded without touching the backbone (same policy as DeviceAgent)
    let adapters = AdapterSet::new(&mut rng, &model.config, AdapterTopology::Skip);
    let batch = train_batch.min(data.len()).max(1);
    let mut tuner = FineTuner::new(model, adapters, Method::Skip2Lora, backend, batch);
    let mut timer = PhaseTimer::new();
    let batches_per_epoch = (data.len() / batch).max(1);
    for _epoch in 0..epochs {
        for _ in 0..batches_per_epoch {
            let idx = rng.sample_with_replacement(data.len(), batch);
            tuner.forward_cached(data, &idx, &mut cache, &mut timer);
            let _ = tuner.backward(&mut timer);
            tuner.update(lr, &mut timer);
        }
    }
    let acc_after = tuner.accuracy(data);
    // publish the trained weights: the adapter struct is weights-only, so
    // the registry snapshot footprint is exactly param_count() floats
    registry.publish(tenant, tuner.adapters.adapters);
    use crate::train::finetuner::{PH_BACKWARD, PH_CACHE, PH_FORWARD, PH_UPDATE};
    AdaptResult {
        tenant,
        cache_hits: cache.stats().hits - hits0,
        cache_misses: cache.stats().misses - misses0,
        cache,
        acc_after,
        train_secs: t0.elapsed().as_secs_f64(),
        forward_ns: timer.total_ns(PH_FORWARD) as u64,
        backward_ns: timer.total_ns(PH_BACKWARD) as u64,
        update_ns: timer.total_ns(PH_UPDATE) as u64,
        cache_ns: timer.total_ns(PH_CACHE) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MlpConfig;
    use crate::tensor::Mat;
    use crate::train::trainer::pretrain;

    fn clustered(seed: u64, n: usize, shift: f32) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, 8);
        let mut labels = Vec::new();
        for i in 0..n {
            let c = i % 3;
            for j in 0..8 {
                let base = if j % 3 == c { 2.0 } else { 0.0 };
                *x.at_mut(i, j) = base + shift + 0.3 * rng.normal();
            }
            labels.push(c);
        }
        Dataset { x, labels, n_classes: 3 }
    }

    fn server_with(workers: usize, inject: u64) -> FleetServer {
        let cfg = MlpConfig { dims: vec![8, 12, 12, 3], rank: 2, batch_norm: true };
        let pre = clustered(0, 120, 0.0);
        let backbone = pretrain(cfg, &pre, 50, 0.05, 1, Backend::Blocked);
        FleetServer::new(
            backbone,
            ServeConfig {
                batch_capacity: 16,
                window: 20,
                accuracy_threshold: 0.7,
                buffer_target: 45,
                epochs: 30,
                lr: 0.05,
                train_batch: 15,
                workers,
                inject_adapt_panics: inject,
                ..Default::default()
            },
        )
    }

    fn server(workers: usize) -> FleetServer {
        server_with(workers, 0)
    }

    fn drive(server: &mut FleetServer, tenant: TenantId, data: &Dataset, feedback: bool) {
        for i in 0..data.len() {
            let x = data.x.row(i).to_vec();
            let req = if feedback {
                Request::Feedback(x, data.labels[i])
            } else {
                Request::Predict(x)
            };
            match server.handle(tenant, req) {
                Response::Queued { .. } => {}
                other => panic!("unexpected response {other:?}"),
            }
            if server.queued() >= server.config().batch_capacity {
                server.pump();
            }
        }
        server.pump_until_drained();
    }

    #[test]
    fn in_distribution_tenants_never_adapt() {
        let mut s = server(0);
        for t in 0..3u64 {
            drive(&mut s, t, &clustered(10 + t, 60, 0.0), true);
        }
        s.quiesce();
        for t in 0..3u64 {
            assert_eq!(s.tenant_adaptations(t), 0, "tenant {t}");
            assert_eq!(s.tenant_feedbacks(t), 60);
            assert!(s.tenant_window_accuracy(t).unwrap() > 0.7);
        }
        assert_eq!(s.registry.publishes(), 0);
    }

    #[test]
    fn drifted_tenant_adapts_and_recovers_without_touching_others() {
        let mut s = server(0);
        // tenant 0 stays clean, tenant 1 drifts hard
        drive(&mut s, 0, &clustered(20, 80, 0.0), true);
        let drifted = clustered(21, 300, 2.5);
        drive(&mut s, 1, &drifted, true);
        s.quiesce();

        assert!(s.tenant_adaptations(1) >= 1, "tenant 1 never adapted");
        assert!(s.tenant_version(1) > 0, "no adapters published");
        let adapt_acc = s.tenant_last_adapt_accuracy(1).unwrap();
        assert!(adapt_acc > 0.7, "fine-tune train accuracy {adapt_acc}");
        assert!(s.metrics.finetune_cache_misses > 0, "first round populates");
        assert_eq!(s.tenant_adaptations(0), 0, "tenant 0 must be untouched");
        assert_eq!(s.tenant_version(0), 0);

        // the fine-tune shared the batcher's backbone by pointer
        assert!(Arc::ptr_eq(s.shared_backbone(), s.lanes.shared_model()));

        // post-adaptation: tenant 1 classifies its drifted distribution
        let probe = clustered(22, 60, 2.5);
        drive(&mut s, 1, &probe, true);
        let acc = s.tenant_window_accuracy(1).unwrap();
        assert!(acc > 0.75, "tenant 1 window accuracy after recovery: {acc}");

        // tenant 0 still accurate with bare backbone
        drive(&mut s, 0, &clustered(23, 40, 0.0), true);
        assert!(s.tenant_window_accuracy(0).unwrap() > 0.7);
    }

    #[test]
    fn background_pool_matches_inline_behavior() {
        let mut s = server(2);
        let drifted = clustered(30, 300, 2.5);
        drive(&mut s, 5, &drifted, true);
        s.quiesce();
        assert!(s.tenant_adaptations(5) >= 1);
        assert!(!s.is_adapting(5), "cache returned after quiesce");
        drive(&mut s, 5, &clustered(31, 60, 2.5), true);
        assert!(s.tenant_window_accuracy(5).unwrap() > 0.75);
        let stats = s.shutdown();
        assert!(stats.publishes >= 1);
        assert_eq!(stats.finetune_panics, 0);
    }

    #[test]
    fn panicking_finetune_job_is_isolated_and_tenant_recovers() {
        // first fine-tune job panics (fault injection): the tenant must
        // come back to a servable state (fresh cache) and the NEXT drift
        // trigger must succeed end to end.
        let mut s = server_with(0, 1);
        let drifted = clustered(40, 400, 2.5);
        drive(&mut s, 9, &drifted, true);
        s.quiesce();

        assert!(s.stats().finetune_panics >= 1, "injected panic not recorded");
        assert!(!s.is_adapting(9), "tenant stranded with cache = None");
        assert!(
            s.tenant_adaptations(9) >= 2,
            "tenant never re-adapted after the panicked job"
        );
        assert!(s.tenant_version(9) > 0, "no adapters published after recovery");

        // post-recovery serving quality on the drifted distribution
        drive(&mut s, 9, &clustered(41, 60, 2.5), true);
        assert!(s.tenant_window_accuracy(9).unwrap() > 0.75);
    }

    #[test]
    fn panicking_job_on_worker_pool_does_not_kill_the_pool() {
        let mut s = server_with(2, 1);
        let drifted = clustered(50, 400, 2.5);
        drive(&mut s, 3, &drifted, true);
        s.quiesce();
        assert!(s.stats().finetune_panics >= 1);
        assert!(s.tenant_adaptations(3) >= 2, "pool died after the panic");
        assert!(s.tenant_version(3) > 0);
        let stats = s.shutdown();
        assert!(stats.publishes >= 1);
    }

    #[test]
    fn swap_adapters_validates_shapes() {
        let mut s = server(0);
        let mut rng = Rng::new(9);
        let bad = vec![LoraAdapter::new(&mut rng, 4, 2, 3)];
        match s.handle(7, Request::SwapAdapters(bad)) {
            Response::Rejected(RejectReason::Malformed(_)) => {}
            other => panic!("expected rejection, got {other:?}"),
        }
        // oversized rank must be rejected up front, not panic the
        // serving loop later (the grouped fan-out's MAX_RANK assert)
        let huge_rank: Vec<LoraAdapter> = [8usize, 12, 12]
            .iter()
            .map(|&n_in| LoraAdapter::new(&mut rng, n_in, MAX_RANK + 1, 3))
            .collect();
        match s.handle(7, Request::SwapAdapters(huge_rank)) {
            Response::Rejected(RejectReason::Malformed(msg)) => {
                assert!(msg.contains("rank"), "{msg}")
            }
            other => panic!("expected rank rejection, got {other:?}"),
        }
        let good: Vec<LoraAdapter> = [8usize, 12, 12]
            .iter()
            .map(|&n_in| LoraAdapter::new(&mut rng, n_in, 2, 3))
            .collect();
        match s.handle(7, Request::SwapAdapters(good)) {
            Response::Swapped { version } => assert!(version > 0),
            other => panic!("expected swap, got {other:?}"),
        }
        assert_eq!(s.tenant_version(7), 1);
    }

    #[test]
    fn stats_roll_up() {
        let mut s = server(0);
        drive(&mut s, 1, &clustered(40, 32, 0.0), false);
        match s.handle(1, Request::Stats) {
            Response::Stats(stats) => {
                assert_eq!(stats.tenants, 1);
                assert_eq!(stats.rows, 32);
                assert!(stats.batches >= 2, "16-cap batcher needed >= 2 flushes");
            }
            other => panic!("expected stats, got {other:?}"),
        }
        assert_eq!(s.metrics.predicts, 32);
    }

    #[test]
    fn rejects_malformed_requests() {
        let mut s = server(0);
        match s.handle(1, Request::Predict(vec![0.0; 3])) {
            Response::Rejected(RejectReason::Malformed(_)) => {}
            other => panic!("{other:?}"),
        }
        match s.handle(1, Request::Feedback(vec![0.0; 8], 99)) {
            Response::Rejected(RejectReason::Malformed(_)) => {}
            other => panic!("{other:?}"),
        }
        // malformed requests never charge admission counters
        let stats = s.stats();
        assert_eq!(stats.queue_rejections, 0);
        assert_eq!(stats.rate_limited, 0);
    }

    #[test]
    fn drain_closes_admissions_and_loses_nothing() {
        let mut s = server(0);
        // stage traffic but do NOT pump: everything sits in the queue
        let data = clustered(60, 24, 0.0);
        for i in 0..data.len() {
            match s.handle(2, Request::Feedback(data.x.row(i).to_vec(), data.labels[i])) {
                Response::Queued { .. } => {}
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(s.queued(), 24);
        let report = s.drain();
        assert_eq!(report.queued_at_start, 24);
        assert_eq!(report.completions.len(), 24, "every accepted request must be served");
        assert_eq!(s.queued(), 0);
        assert!(!s.any_adapting());
        // data plane closed: typed rejection, not a drop or a panic
        match s.handle(2, Request::Predict(data.x.row(0).to_vec())) {
            Response::Rejected(RejectReason::Draining) => {}
            other => panic!("expected Draining rejection, got {other:?}"),
        }
        match s.handle(2, Request::Feedback(data.x.row(0).to_vec(), 0)) {
            Response::Rejected(RejectReason::Draining) => {}
            other => panic!("expected Draining rejection, got {other:?}"),
        }
        // admin plane stays open mid-drain (migration/observability path)
        match s.handle(0, Request::Observe) {
            Response::Observed(_) => {}
            other => panic!("{other:?}"),
        }
        // books balance: everything admitted was completed, rejections typed
        assert_eq!(s.metrics.feedbacks, 24);
        assert!(s.is_draining());
        // resume re-opens the data plane for the remaining tenants
        s.resume_admissions();
        match s.handle(2, Request::Predict(data.x.row(0).to_vec())) {
            Response::Queued { .. } => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(s.pump_until_drained().len(), 1);
    }

    #[test]
    fn drain_joins_inflight_finetunes() {
        let mut s = server(2);
        let drifted = clustered(61, 300, 2.5);
        for i in 0..drifted.len() {
            match s.handle(4, Request::Feedback(drifted.x.row(i).to_vec(), drifted.labels[i]))
            {
                Response::Queued { .. } => {}
                other => panic!("{other:?}"),
            }
            if s.queued() >= s.config().batch_capacity {
                s.pump();
            }
        }
        let report = s.drain();
        assert!(!s.any_adapting(), "drain must join in-flight fine-tune jobs");
        assert!(s.tenant_adaptations(4) >= 1);
        assert!(s.tenant_version(4) > 0, "joined fine-tune published its adapters");
        // the drain flushed the residual queue; nothing admitted was lost
        assert_eq!(s.metrics.feedbacks, drifted.len() as u64);
        assert_eq!(s.queued(), 0);
        drop(report);
        s.shutdown();
    }

    #[test]
    fn queue_full_gets_typed_rejection_and_is_counted() {
        let mut s = FleetServer::new(
            {
                let cfg =
                    MlpConfig { dims: vec![8, 12, 12, 3], rank: 2, batch_norm: true };
                let pre = clustered(0, 120, 0.0);
                pretrain(cfg, &pre, 50, 0.05, 1, Backend::Blocked)
            },
            ServeConfig { batch_capacity: 4, queue_bound: 6, ..Default::default() },
        );
        let data = clustered(1, 10, 0.0);
        let mut queued = 0;
        let mut rejected = 0;
        for i in 0..10 {
            match s.handle(1, Request::Predict(data.x.row(i).to_vec())) {
                Response::Queued { .. } => queued += 1,
                Response::Rejected(RejectReason::QueueFull { bound }) => {
                    assert_eq!(bound, 6);
                    rejected += 1;
                }
                other => panic!("{other:?}"),
            }
            assert!(s.queued() <= 6, "queue grew past its bound");
        }
        assert_eq!((queued, rejected), (6, 4));
        assert_eq!(s.stats().queue_rejections, 4);
        // every ADMITTED request is served; the rejected ones are gone
        assert_eq!(s.pump_until_drained().len(), 6);
        assert_eq!(s.stats().queued, 0);
    }

    #[test]
    fn token_bucket_bursts_then_sustains_the_configured_rate() {
        let mut s = FleetServer::new(
            {
                let cfg =
                    MlpConfig { dims: vec![8, 12, 12, 3], rank: 2, batch_norm: true };
                let pre = clustered(0, 120, 0.0);
                pretrain(cfg, &pre, 50, 0.05, 1, Backend::Blocked)
            },
            ServeConfig {
                batch_capacity: 16,
                rate_limit: Some(RateLimit { burst: 3.0, tokens_per_pump: 1.0 }),
                ..Default::default()
            },
        );
        let data = clustered(2, 10, 0.0);
        let x = || data.x.row(0).to_vec();
        // burst: exactly `burst` requests admitted on tick 0
        let mut admitted = 0;
        for _ in 0..8 {
            match s.handle(1, Request::Predict(x())) {
                Response::Queued { .. } => admitted += 1,
                Response::Rejected(RejectReason::RateLimited) => {}
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(admitted, 3, "burst must cap instant admission");
        assert_eq!(s.stats().rate_limited, 5);
        // one pump drips one token: exactly one more admission
        s.pump();
        match s.handle(1, Request::Predict(x())) {
            Response::Queued { .. } => {}
            other => panic!("{other:?}"),
        }
        match s.handle(1, Request::Predict(x())) {
            Response::Rejected(RejectReason::RateLimited) => {}
            other => panic!("{other:?}"),
        }
        // OTHER tenants have their own buckets — unaffected
        match s.handle(2, Request::Predict(x())) {
            Response::Queued { .. } => {}
            other => panic!("{other:?}"),
        }
        s.pump_until_drained();
    }

    #[test]
    fn idle_tenant_is_evicted_and_readmitted_with_latest_adapters() {
        let mut s = FleetServer::new(
            {
                let cfg =
                    MlpConfig { dims: vec![8, 12, 12, 3], rank: 2, batch_norm: true };
                let pre = clustered(0, 120, 0.0);
                pretrain(cfg, &pre, 50, 0.05, 1, Backend::Blocked)
            },
            ServeConfig {
                batch_capacity: 4,
                idle_ttl_pumps: Some(8),
                ..Default::default()
            },
        );
        let mut rng = Rng::new(13);
        let ads: Vec<LoraAdapter> = [8usize, 12, 12]
            .iter()
            .map(|&n_in| LoraAdapter::new(&mut rng, n_in, 2, 3))
            .collect();
        let version = match s.handle(5, Request::SwapAdapters(ads)) {
            Response::Swapped { version } => version,
            other => panic!("{other:?}"),
        };
        let data = clustered(3, 10, 0.0);
        s.handle(5, Request::Feedback(data.x.row(0).to_vec(), data.labels[0]));
        s.pump_until_drained();
        assert_eq!(s.tenant_feedbacks(5), 1);
        assert_eq!(s.tenant_count(), 1);

        // idle past the TTL: serve-side state is swept...
        for _ in 0..20 {
            s.pump();
        }
        assert_eq!(s.tenant_count(), 0, "idle tenant not evicted");
        assert!(s.stats().evictions >= 1);
        // ...but the published adapters are NOT dropped
        assert_eq!(s.tenant_version(5), version);

        // transparent re-admission: served with the latest snapshot and
        // a fresh (empty) serve state
        match s.handle(5, Request::Predict(data.x.row(1).to_vec())) {
            Response::Queued { .. } => {}
            other => panic!("{other:?}"),
        }
        let done = s.pump_until_drained();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].adapter_version, version, "latest adapters served");
        assert_eq!(s.tenant_count(), 1, "tenant re-admitted");
        assert_eq!(s.tenant_feedbacks(5), 0, "fresh serve state after eviction");
    }

    #[test]
    fn save_and_restore_requests_roundtrip_via_handle() {
        let dir = std::env::temp_dir().join("s2l_server_persist");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.s2l");

        let mut s = server(0);
        let mut rng = Rng::new(21);
        let ads: Vec<LoraAdapter> = [8usize, 12, 12]
            .iter()
            .map(|&n_in| LoraAdapter::new(&mut rng, n_in, 2, 3))
            .collect();
        let version = match s.handle(4, Request::SwapAdapters(ads)) {
            Response::Swapped { version } => version,
            other => panic!("{other:?}"),
        };
        match s.handle(0, Request::SaveState(path.clone())) {
            Response::Persisted(report) => {
                assert_eq!(report.tenants, 1);
                assert!(report.bytes > 0);
            }
            other => panic!("{other:?}"),
        }

        // a FRESH server on the same backbone config picks the state up
        let mut s2 = server(0);
        assert_eq!(s2.tenant_version(4), 0);
        match s2.handle(0, Request::RestoreState(path.clone())) {
            Response::Restored(report) => {
                assert_eq!(report.tenants, 1);
                assert_eq!(report.installed, 1);
                assert_eq!(report.max_version, version);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s2.tenant_version(4), version, "exact persisted version");
        let stats = s2.stats();
        assert_eq!((stats.persists, stats.restores), (0, 1));
        assert_eq!(s.stats().persists, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn persist_failures_are_typed_not_panics() {
        let mut s = server(0);
        // unwritable path
        match s.handle(0, Request::SaveState(PathBuf::from("/definitely/not/a/dir/x.s2l"))) {
            Response::Rejected(RejectReason::PersistFailed(msg)) => {
                assert!(msg.contains("persist"), "{msg}")
            }
            other => panic!("{other:?}"),
        }
        // missing checkpoint
        match s.handle(0, Request::RestoreState(PathBuf::from("/no/such/checkpoint.s2l"))) {
            Response::Rejected(RejectReason::PersistFailed(_)) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(s.stats().persists, 0);
        assert_eq!(s.stats().restores, 0);
    }

    #[test]
    fn export_import_runs_the_swap_validation() {
        let mut a = server(0);
        let mut rng = Rng::new(22);
        let ads: Vec<LoraAdapter> = [8usize, 12, 12]
            .iter()
            .map(|&n_in| LoraAdapter::new(&mut rng, n_in, 2, 3))
            .collect();
        a.handle(11, Request::SwapAdapters(ads));
        assert!(a.export_tenant(999).is_err(), "unknown tenant must not export");
        let bytes = a.export_tenant(11).unwrap();

        let mut b = server(0);
        let (tenant, version) = b.import_tenant(&bytes).unwrap();
        assert_eq!(tenant, 11);
        assert!(version > 0);
        // imported weights are bit-identical to the exported snapshot
        let from_a = a.registry.snapshot(11).unwrap();
        let from_b = b.registry.snapshot(11).unwrap();
        for (x, y) in from_a.adapters.iter().zip(&from_b.adapters) {
            assert_eq!(x.wa, y.wa);
            assert_eq!(x.wb, y.wb);
        }
        // garbage payloads are typed errors
        assert!(b.import_tenant(b"not an s2l file").is_err());
    }

    #[test]
    fn lone_trailing_request_is_served_by_the_deadline() {
        let mut s = server(0);
        let data = clustered(60, 1, 0.0);
        s.handle(1, Request::Predict(data.x.row(0).to_vec()));
        // far below batch_capacity: only the deadline can flush it
        let mut served = 0;
        for _ in 0..s.config().flush_deadline_pumps + 1 {
            served += s.pump().len();
        }
        assert_eq!(served, 1, "lone request must not wait forever");
    }
}
