//! Versioned per-tenant adapter registry with copy-on-write snapshots.
//!
//! The whole point of the Skip-LoRA split for fleet serving: a tenant's
//! entire personalization is a few KB of adapter weights (`nn::lora`), so
//! publishing a new fine-tuned version is ONE pointer swap under a short
//! write lock, and readers never block on writers — they hold `Arc`
//! snapshots that stay immutable and alive for as long as they need them.
//! A fine-tune job that publishes mid-request cannot tear a reader's view:
//! the reader either sees the old complete set or the new complete set
//! (verified by the concurrency property test in
//! `tests/serve_subsystem.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::nn::lora::LoraAdapter;

/// Tenant identifier (a device / user / deployment slot).
pub type TenantId = u64;

/// One immutable published adapter set. Never mutated after publish —
/// hand out `Arc<AdapterSnapshot>` freely across threads.
#[derive(Clone, Debug)]
pub struct AdapterSnapshot {
    pub tenant: TenantId,
    /// Globally monotone publish version (also monotone per tenant).
    pub version: u64,
    /// Skip adapters, one per backbone layer (adapter k: N_k -> M_n).
    pub adapters: Vec<LoraAdapter>,
}

impl AdapterSnapshot {
    /// Heap footprint of this adapter set (the "few KB per tenant" claim).
    pub fn byte_size(&self) -> usize {
        self.adapters
            .iter()
            .map(|a| a.param_count() * std::mem::size_of::<f32>())
            .sum()
    }
}

/// The registry: tenant -> latest published snapshot.
#[derive(Debug, Default)]
pub struct AdapterRegistry {
    map: RwLock<HashMap<TenantId, Arc<AdapterSnapshot>>>,
    next_version: AtomicU64,
    publishes: AtomicU64,
}

impl AdapterRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish a new adapter set for `tenant`, replacing any previous
    /// version atomically. Returns the version allocated to THIS publish.
    ///
    /// Per-tenant versions are monotone even under racing publishers
    /// (e.g. a background fine-tune job vs a `SwapAdapters` request): the
    /// installed snapshot is compared under the write lock, so a stale
    /// publisher can never overwrite a newer version — its publish is a
    /// no-op and the newer adapters stay live.
    pub fn publish(&self, tenant: TenantId, adapters: Vec<LoraAdapter>) -> u64 {
        let version = self.next_version.fetch_add(1, Ordering::Relaxed) + 1;
        let snap = Arc::new(AdapterSnapshot {
            tenant,
            version,
            adapters,
        });
        {
            let mut map = self.map.write().expect("registry lock poisoned");
            let newer_installed = map
                .get(&tenant)
                .is_some_and(|cur| cur.version > version);
            if !newer_installed {
                map.insert(tenant, snap);
            }
        }
        self.publishes.fetch_add(1, Ordering::Relaxed);
        version
    }

    /// Latest snapshot for `tenant` (an `Arc` clone — O(1), never blocks
    /// publishers for longer than the read lock).
    pub fn snapshot(&self, tenant: TenantId) -> Option<Arc<AdapterSnapshot>> {
        self.map
            .read()
            .expect("registry lock poisoned")
            .get(&tenant)
            .cloned()
    }

    /// Latest snapshots for a batch of tenants under ONE read-lock
    /// acquisition — the serving fan-out path (`MicroBatcher::flush`)
    /// uses this so a B-row micro-batch costs one lock, not B.
    /// Missing tenants are simply absent from the result.
    pub fn snapshot_many(
        &self,
        tenants: impl IntoIterator<Item = TenantId>,
    ) -> HashMap<TenantId, Arc<AdapterSnapshot>> {
        let map = self.map.read().expect("registry lock poisoned");
        let mut out = HashMap::new();
        for t in tenants {
            if let Some(snap) = map.get(&t) {
                out.entry(t).or_insert_with(|| Arc::clone(snap));
            }
        }
        out
    }

    /// Latest published version for `tenant` (0 = never published).
    pub fn version(&self, tenant: TenantId) -> u64 {
        self.snapshot(tenant).map_or(0, |s| s.version)
    }

    /// Drop a tenant's adapters (fall back to the bare backbone).
    pub fn remove(&self, tenant: TenantId) -> bool {
        self.map
            .write()
            .expect("registry lock poisoned")
            .remove(&tenant)
            .is_some()
    }

    pub fn tenant_count(&self) -> usize {
        self.map.read().expect("registry lock poisoned").len()
    }

    /// Sorted tenant ids (diagnostics / iteration in tests).
    pub fn tenants(&self) -> Vec<TenantId> {
        let mut v: Vec<TenantId> = self
            .map
            .read()
            .expect("registry lock poisoned")
            .keys()
            .copied()
            .collect();
        v.sort_unstable();
        v
    }

    /// Total publishes since creation.
    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }

    /// Fleet-wide adapter footprint in bytes.
    pub fn total_adapter_bytes(&self) -> usize {
        self.map
            .read()
            .expect("registry lock poisoned")
            .values()
            .map(|s| s.byte_size())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn adapters(rng: &mut Rng) -> Vec<LoraAdapter> {
        (0..3).map(|_| LoraAdapter::new(rng, 8, 2, 3)).collect()
    }

    #[test]
    fn publish_bumps_version_and_replaces() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(0);
        assert_eq!(reg.version(7), 0);
        let v1 = reg.publish(7, adapters(&mut rng));
        let v2 = reg.publish(7, adapters(&mut rng));
        assert!(v2 > v1);
        assert_eq!(reg.version(7), v2);
        assert_eq!(reg.tenant_count(), 1);
        assert_eq!(reg.publishes(), 2);
    }

    #[test]
    fn old_snapshots_survive_republish() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(1);
        reg.publish(1, adapters(&mut rng));
        let old = reg.snapshot(1).unwrap();
        let old_wa = old.adapters[0].wa.data.clone();
        reg.publish(1, adapters(&mut rng));
        // the held snapshot is untouched (copy-on-write semantics)
        assert_eq!(old.adapters[0].wa.data, old_wa);
        assert_ne!(reg.snapshot(1).unwrap().version, old.version);
    }

    #[test]
    fn per_tenant_isolation() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(2);
        reg.publish(1, adapters(&mut rng));
        reg.publish(2, adapters(&mut rng));
        let v1 = reg.version(1);
        reg.publish(2, adapters(&mut rng));
        assert_eq!(reg.version(1), v1, "tenant 1 unaffected by tenant 2");
        assert!(reg.remove(2));
        assert!(reg.snapshot(2).is_none());
        assert!(reg.snapshot(1).is_some());
        assert_eq!(reg.tenants(), vec![1]);
    }

    #[test]
    fn byte_size_counts_adapter_params() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(3);
        reg.publish(1, adapters(&mut rng));
        // 3 adapters x (8*2 + 2*3) params x 4 bytes
        assert_eq!(reg.total_adapter_bytes(), 3 * (8 * 2 + 2 * 3) * 4);
    }
}
