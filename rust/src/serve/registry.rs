//! Versioned per-tenant adapter registry, sharded by tenant-id hash.
//!
//! The whole point of the Skip-LoRA split for fleet serving: a tenant's
//! entire personalization is a few KB of adapter weights (`nn::lora`), so
//! publishing a new fine-tuned version is ONE pointer swap under a short
//! write lock, and readers never block on writers — they hold `Arc`
//! snapshots that stay immutable and alive for as long as they need them.
//! A fine-tune job that publishes mid-request cannot tear a reader's view:
//! the reader either sees the old complete set or the new complete set
//! (verified by the concurrency property test in
//! `tests/serve_subsystem.rs`).
//!
//! ## Sharding
//!
//! A single `RwLock<HashMap>` is a fleet-wide point of contention: every
//! publish briefly stalls every reader, and past ~10⁵ tenants the lock
//! (not the adapter math) becomes the serving bottleneck. The registry is
//! therefore split into `shard_count()` independent shards, each its own
//! `RwLock<HashMap>`. A tenant id routes to exactly one shard via a
//! SplitMix64 finalizer (a pure function of the id and the shard count, so
//! the same tenant ALWAYS lands on the same shard — property-tested in
//! `tests/serve_subsystem.rs`), which means:
//!
//! * per-tenant version monotonicity needs only the shard-local write
//!   lock (the global version counter is an atomic, never a lock);
//! * publishers on different shards never contend with each other or
//!   with readers of other shards;
//! * [`AdapterRegistry::snapshot_many`] groups a micro-batch's tenants by
//!   shard and takes one read lock per DISTINCT shard touched, not one
//!   per request.
//!
//! `benches/serve_micro.rs` quantifies the sharded-vs-single-lock read
//! throughput under concurrent publish load.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::nn::lora::LoraAdapter;
use crate::util::rng::SplitMix64;

/// Tenant identifier (a device / user / deployment slot).
pub type TenantId = u64;

/// Default shard count (power of two; `with_shards` to override).
pub const DEFAULT_SHARDS: usize = 16;

/// One immutable published adapter set. Never mutated after publish —
/// hand out `Arc<AdapterSnapshot>` freely across threads.
#[derive(Clone, Debug)]
pub struct AdapterSnapshot {
    pub tenant: TenantId,
    /// Globally monotone publish version (also monotone per tenant).
    pub version: u64,
    /// Skip adapters, one per backbone layer (adapter k: N_k -> M_n).
    pub adapters: Vec<LoraAdapter>,
    /// Provenance: `None` for snapshots published by live work in THIS
    /// process ([`AdapterRegistry::publish`]); `Some(capture_micros)` for
    /// snapshots installed from a checkpoint captured at that wall-clock
    /// stamp. Version numbers reset across process restarts, so
    /// [`AdapterRegistry::restore`] orders conflicting snapshots by
    /// provenance (live > later-captured checkpoint > earlier-captured
    /// checkpoint), never by raw version numbers alone.
    pub restored_from_micros: Option<u64>,
}

impl AdapterSnapshot {
    /// Heap footprint of this adapter set (the "few KB per tenant" claim).
    pub fn byte_size(&self) -> usize {
        self.adapters
            .iter()
            .map(|a| a.param_count() * std::mem::size_of::<f32>())
            .sum()
    }
}

/// One shard: an independent tenant → snapshot map plus lock-traffic
/// counters (the per-shard contention signal surfaced in `ShardStats`).
/// The counters track TENANT-ROUTED operations only — whole-registry
/// aggregates (`tenant_count`, `tenants`, `total_adapter_bytes`,
/// `shard_stats`) touch every shard uniformly and would only dilute the
/// routing-skew signal the counters exist to expose.
#[derive(Debug, Default)]
struct Shard {
    map: RwLock<HashMap<TenantId, Arc<AdapterSnapshot>>>,
    /// tenant-routed read-lock acquisitions (snapshot / snapshot_many)
    reads: AtomicU64,
    /// tenant-routed write-lock acquisitions (publish / remove)
    writes: AtomicU64,
}

/// Per-shard diagnostics: how many tenants the shard holds and how much
/// lock traffic it has absorbed. A heavily skewed `reads`/`writes` across
/// shards would indicate a routing hot spot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardStats {
    pub tenants: usize,
    pub reads: u64,
    pub writes: u64,
}

/// Reusable scratch for [`AdapterRegistry::snapshot_many_into`] — the
/// zero-alloc serving fan-out's registry read path. Owns the per-shard
/// grouping vectors and the tenant → snapshot result map; both keep
/// their capacity across calls, so a warm batch lookup allocates
/// nothing.
#[derive(Debug, Default)]
pub struct SnapshotBatch {
    /// tenants grouped by destination shard (scratch, cleared per call)
    by_shard: Vec<Vec<TenantId>>,
    /// the result of the most recent `snapshot_many_into`
    map: HashMap<TenantId, Arc<AdapterSnapshot>>,
}

impl SnapshotBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot for `tenant` from the most recent batch lookup.
    pub fn get(&self, tenant: TenantId) -> Option<&Arc<AdapterSnapshot>> {
        self.map.get(&tenant)
    }

    /// Distinct tenants resolved by the most recent batch lookup.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// The registry: tenant -> latest published snapshot, sharded by
/// tenant-id hash.
#[derive(Debug)]
pub struct AdapterRegistry {
    shards: Box<[Shard]>,
    /// `shards.len() - 1`; shard count is always a power of two
    mask: u64,
    next_version: AtomicU64,
    publishes: AtomicU64,
}

impl Default for AdapterRegistry {
    fn default() -> Self {
        Self::with_shards(DEFAULT_SHARDS)
    }
}

impl AdapterRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Registry with `shards` shards (rounded up to a power of two,
    /// minimum 1). `with_shards(1)` is the old single-lock registry —
    /// the bench baseline.
    pub fn with_shards(shards: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        Self {
            shards: (0..n).map(|_| Shard::default()).collect(),
            mask: (n - 1) as u64,
            next_version: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard `tenant` routes to — one `SplitMix64` step (the same
    /// mixer the RNG substrate seeds with), a pure function of the id and
    /// the shard count, so the same tenant always lands on the same
    /// shard. Sequential tenant ids scatter across shards.
    #[inline]
    pub fn shard_of(&self, tenant: TenantId) -> usize {
        (SplitMix64::new(tenant).next_u64() & self.mask) as usize
    }

    #[inline]
    fn shard(&self, tenant: TenantId) -> &Shard {
        &self.shards[self.shard_of(tenant)]
    }

    /// Publish a new adapter set for `tenant`, replacing any previous
    /// version atomically. Returns the version allocated to THIS publish.
    ///
    /// Per-tenant versions are monotone even under racing publishers
    /// (e.g. a background fine-tune job vs a `SwapAdapters` request):
    /// a tenant lives on exactly one shard, and the installed snapshot is
    /// compared under that shard's write lock, so a stale publisher can
    /// never overwrite a newer version — its publish is a no-op and the
    /// newer adapters stay live. Publishers on OTHER shards proceed in
    /// parallel, untouched.
    pub fn publish(&self, tenant: TenantId, adapters: Vec<LoraAdapter>) -> u64 {
        let version = self.next_version.fetch_add(1, Ordering::Relaxed) + 1;
        let snap = Arc::new(AdapterSnapshot {
            tenant,
            version,
            adapters,
            restored_from_micros: None,
        });
        let shard = self.shard(tenant);
        shard.writes.fetch_add(1, Ordering::Relaxed);
        {
            let mut map = shard.map.write().expect("registry shard poisoned");
            let newer_installed = map
                .get(&tenant)
                .is_some_and(|cur| cur.version > version);
            if !newer_installed {
                map.insert(tenant, snap);
            }
        }
        self.publishes.fetch_add(1, Ordering::Relaxed);
        version
    }

    /// Re-install a PERSISTED snapshot at its exact persisted version —
    /// the restore half of `serve::persist`. Returns `true` if the
    /// snapshot was installed, `false` if the registry kept what it has.
    ///
    /// Raw version numbers reset across process restarts, so conflicts
    /// are ordered by PROVENANCE, not version (the decision runs under
    /// the shard write lock, so a racing fine-tune publish cannot be
    /// clobbered):
    ///
    /// * a LOCALLY PUBLISHED current snapshot always wins — a pre-crash
    ///   checkpoint can carry a bigger number than adapters a tenant
    ///   just retrained post-crash, and the retrain must survive;
    /// * two checkpoint-installed snapshots are ordered by their
    ///   checkpoints' capture stamps — the LATER-captured checkpoint is
    ///   the newer truth even where its raw versions are smaller
    ///   (restoring checkpoints out of order can never resurrect older
    ///   weights); equal stamps (the same checkpoint re-applied) fall
    ///   back to the version compare, making re-restore idempotent.
    ///
    /// Monotonicity of PUBLISHES is preserved on both axes:
    /// * per tenant — the compare-and-install runs under the tenant's
    ///   shard write lock, exactly like [`AdapterRegistry::publish`];
    /// * globally — the version counter is raised to at least
    ///   `snap.version` FIRST (`fetch_max`), so every post-restore
    ///   publish allocates a version strictly greater than anything
    ///   restored (this floor-raise happens even when the install is
    ///   skipped, healing the version-domain reset going forward).
    pub fn restore(&self, snap: Arc<AdapterSnapshot>) -> bool {
        assert!(snap.version > 0, "published versions start at 1");
        self.next_version.fetch_max(snap.version, Ordering::Relaxed);
        let shard = self.shard(snap.tenant);
        shard.writes.fetch_add(1, Ordering::Relaxed);
        let mut map = shard.map.write().expect("registry shard poisoned");
        let keep_current = map.get(&snap.tenant).is_some_and(|cur| {
            match (cur.restored_from_micros, snap.restored_from_micros) {
                // live-published state always beats checkpoint data
                (None, _) => true,
                // two checkpoints: later capture wins; same capture
                // falls back to versions (idempotent re-restore)
                (Some(cur_at), Some(new_at)) => {
                    cur_at > new_at || (cur_at == new_at && cur.version >= snap.version)
                }
                // incoming carries live-provenance weights (in-memory
                // capture -> restore_into migration): newer than any
                // disk checkpoint
                (Some(_), None) => false,
            }
        });
        if keep_current {
            false
        } else {
            map.insert(snap.tenant, snap);
            true
        }
    }

    /// Raise the global version counter to at least `v` without
    /// installing anything — restoring a checkpoint's `next_version`
    /// ensures post-restore publishes outrank every PERSISTED version,
    /// even for tenants whose snapshots were rejected or absent.
    pub fn raise_version_floor(&self, v: u64) {
        self.next_version.fetch_max(v, Ordering::Relaxed);
    }

    /// The most recently allocated global version (0 = nothing published
    /// yet). Every snapshot version ever handed out is ≤ this.
    pub fn current_version(&self) -> u64 {
        self.next_version.load(Ordering::Relaxed)
    }

    /// Latest snapshot for `tenant` (an `Arc` clone — O(1), never blocks
    /// publishers on other shards, and blocks same-shard publishers for
    /// no longer than the shard read lock).
    pub fn snapshot(&self, tenant: TenantId) -> Option<Arc<AdapterSnapshot>> {
        let shard = self.shard(tenant);
        shard.reads.fetch_add(1, Ordering::Relaxed);
        shard
            .map
            .read()
            .expect("registry shard poisoned")
            .get(&tenant)
            .cloned()
    }

    /// Latest snapshots for a batch of tenants with ONE read-lock
    /// acquisition per DISTINCT shard touched — so a B-row micro-batch
    /// costs at most `min(B, shard_count)` locks, not B. Allocating
    /// convenience wrapper over [`AdapterRegistry::snapshot_many_into`];
    /// the serving fan-out (`MicroBatcher::flush`) uses the `_into` form
    /// with a batcher-owned [`SnapshotBatch`] so the steady state is
    /// allocation-free. Missing tenants are simply absent.
    pub fn snapshot_many(
        &self,
        tenants: impl IntoIterator<Item = TenantId>,
    ) -> HashMap<TenantId, Arc<AdapterSnapshot>> {
        let mut batch = SnapshotBatch::new();
        self.snapshot_many_into(tenants, &mut batch);
        batch.map
    }

    /// [`AdapterRegistry::snapshot_many`] into caller-owned scratch:
    /// after the first call with a given tenant-set size, subsequent
    /// calls allocate nothing (the shard-grouping vectors and the result
    /// map keep their capacity; `Arc` clones never allocate).
    pub fn snapshot_many_into(
        &self,
        tenants: impl IntoIterator<Item = TenantId>,
        batch: &mut SnapshotBatch,
    ) {
        batch.map.clear();
        // group by shard first, then lock each touched shard exactly once
        if batch.by_shard.len() != self.shards.len() {
            batch.by_shard.resize_with(self.shards.len(), Vec::new);
        }
        for v in batch.by_shard.iter_mut() {
            v.clear();
        }
        for t in tenants {
            batch.by_shard[self.shard_of(t)].push(t);
        }
        for (shard, wanted) in self.shards.iter().zip(&batch.by_shard) {
            if wanted.is_empty() {
                continue;
            }
            shard.reads.fetch_add(1, Ordering::Relaxed);
            let map = shard.map.read().expect("registry shard poisoned");
            for &t in wanted {
                if let Some(snap) = map.get(&t) {
                    batch.map.entry(t).or_insert_with(|| Arc::clone(snap));
                }
            }
        }
    }

    /// Latest published version for `tenant` (0 = never published).
    pub fn version(&self, tenant: TenantId) -> u64 {
        self.snapshot(tenant).map_or(0, |s| s.version)
    }

    /// Drop a tenant's adapters (fall back to the bare backbone).
    pub fn remove(&self, tenant: TenantId) -> bool {
        let shard = self.shard(tenant);
        shard.writes.fetch_add(1, Ordering::Relaxed);
        shard
            .map
            .write()
            .expect("registry shard poisoned")
            .remove(&tenant)
            .is_some()
    }

    pub fn tenant_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.map.read().expect("registry shard poisoned").len())
            .sum()
    }

    /// Sorted tenant ids across all shards (diagnostics / tests).
    pub fn tenants(&self) -> Vec<TenantId> {
        let mut v: Vec<TenantId> = (0..self.shards.len())
            .flat_map(|i| self.shard_tenants(i))
            .collect();
        v.sort_unstable();
        v
    }

    /// Sorted tenant ids held by shard `i`. The union over all shards is
    /// exactly `tenants()` and the per-shard sets are disjoint
    /// (property-tested).
    pub fn shard_tenants(&self, i: usize) -> Vec<TenantId> {
        let shard = &self.shards[i];
        let mut v: Vec<TenantId> = shard
            .map
            .read()
            .expect("registry shard poisoned")
            .keys()
            .copied()
            .collect();
        v.sort_unstable();
        v
    }

    /// Per-shard occupancy and lock-traffic counters.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .map(|s| ShardStats {
                tenants: s.map.read().expect("registry shard poisoned").len(),
                reads: s.reads.load(Ordering::Relaxed),
                writes: s.writes.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Total publishes since creation.
    pub fn publishes(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }

    /// Fleet-wide adapter footprint in bytes.
    pub fn total_adapter_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.map
                    .read()
                    .expect("registry shard poisoned")
                    .values()
                    .map(|snap| snap.byte_size())
                    .sum::<usize>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn adapters(rng: &mut Rng) -> Vec<LoraAdapter> {
        (0..3).map(|_| LoraAdapter::new(rng, 8, 2, 3)).collect()
    }

    #[test]
    fn publish_bumps_version_and_replaces() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(0);
        assert_eq!(reg.version(7), 0);
        let v1 = reg.publish(7, adapters(&mut rng));
        let v2 = reg.publish(7, adapters(&mut rng));
        assert!(v2 > v1);
        assert_eq!(reg.version(7), v2);
        assert_eq!(reg.tenant_count(), 1);
        assert_eq!(reg.publishes(), 2);
    }

    #[test]
    fn old_snapshots_survive_republish() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(1);
        reg.publish(1, adapters(&mut rng));
        let old = reg.snapshot(1).unwrap();
        let old_wa = old.adapters[0].wa.data.clone();
        reg.publish(1, adapters(&mut rng));
        // the held snapshot is untouched (copy-on-write semantics)
        assert_eq!(old.adapters[0].wa.data, old_wa);
        assert_ne!(reg.snapshot(1).unwrap().version, old.version);
    }

    #[test]
    fn per_tenant_isolation() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(2);
        reg.publish(1, adapters(&mut rng));
        reg.publish(2, adapters(&mut rng));
        let v1 = reg.version(1);
        reg.publish(2, adapters(&mut rng));
        assert_eq!(reg.version(1), v1, "tenant 1 unaffected by tenant 2");
        assert!(reg.remove(2));
        assert!(reg.snapshot(2).is_none());
        assert!(reg.snapshot(1).is_some());
        assert_eq!(reg.tenants(), vec![1]);
    }

    #[test]
    fn byte_size_counts_adapter_params() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(3);
        reg.publish(1, adapters(&mut rng));
        // 3 adapters x (8*2 + 2*3) params x 4 bytes
        assert_eq!(reg.total_adapter_bytes(), 3 * (8 * 2 + 2 * 3) * 4);
    }

    #[test]
    fn shard_count_rounds_up_to_power_of_two() {
        assert_eq!(AdapterRegistry::with_shards(0).shard_count(), 1);
        assert_eq!(AdapterRegistry::with_shards(1).shard_count(), 1);
        assert_eq!(AdapterRegistry::with_shards(5).shard_count(), 8);
        assert_eq!(AdapterRegistry::with_shards(16).shard_count(), 16);
        assert_eq!(AdapterRegistry::new().shard_count(), DEFAULT_SHARDS);
    }

    #[test]
    fn routing_is_stable_and_in_range() {
        let reg = AdapterRegistry::with_shards(8);
        for t in 0..1000u64 {
            let s = reg.shard_of(t);
            assert!(s < reg.shard_count());
            assert_eq!(s, reg.shard_of(t), "routing must be deterministic");
        }
    }

    #[test]
    fn tenants_spread_across_shards() {
        // sequential ids must NOT all land on one shard (the hash mixes)
        let reg = AdapterRegistry::with_shards(8);
        let mut rng = Rng::new(4);
        for t in 0..256u64 {
            reg.publish(t, adapters(&mut rng));
        }
        let stats = reg.shard_stats();
        let occupied = stats.iter().filter(|s| s.tenants > 0).count();
        assert_eq!(occupied, 8, "all shards should hold tenants: {stats:?}");
        let max = stats.iter().map(|s| s.tenants).max().unwrap();
        assert!(max < 256 / 2, "heavily skewed routing: {stats:?}");
    }

    #[test]
    fn snapshot_many_crosses_shards() {
        let reg = AdapterRegistry::with_shards(4);
        let mut rng = Rng::new(5);
        for t in 0..32u64 {
            reg.publish(t, adapters(&mut rng));
        }
        let snaps = reg.snapshot_many((0..40u64).chain([7, 7])); // dups + missing
        assert_eq!(snaps.len(), 32);
        for (t, snap) in &snaps {
            assert_eq!(snap.tenant, *t);
        }
    }

    #[test]
    fn snapshot_many_into_reuses_scratch_and_matches_the_allocating_form() {
        let reg = AdapterRegistry::with_shards(4);
        let mut rng = Rng::new(12);
        for t in 0..24u64 {
            reg.publish(t, adapters(&mut rng));
        }
        let want = reg.snapshot_many((0..30u64).chain([3, 3]));
        let mut batch = SnapshotBatch::new();
        reg.snapshot_many_into((0..30u64).chain([3, 3]), &mut batch);
        assert_eq!(batch.len(), want.len());
        for (t, snap) in &want {
            let got = batch.get(*t).expect("tenant resolved");
            assert!(Arc::ptr_eq(got, snap), "same published Arc");
        }
        assert!(batch.get(999).is_none());
        // a repeat call with the same shape reuses both the map and the
        // shard-grouping vectors (capacities already sufficient)
        reg.snapshot_many_into((0..30u64).chain([3, 3]), &mut batch);
        assert_eq!(batch.len(), 24);
        // publish-version visibility: a new publish shows on the NEXT call
        let v = reg.publish(3, adapters(&mut rng));
        reg.snapshot_many_into([3u64], &mut batch);
        assert_eq!(batch.get(3).unwrap().version, v);
        assert_eq!(batch.len(), 1, "stale entries cleared per call");
    }

    #[test]
    fn single_shard_registry_still_works() {
        let reg = AdapterRegistry::with_shards(1);
        let mut rng = Rng::new(6);
        for t in 0..10u64 {
            reg.publish(t, adapters(&mut rng));
        }
        assert_eq!(reg.tenant_count(), 10);
        assert_eq!(reg.shard_tenants(0), (0..10u64).collect::<Vec<_>>());
    }

    /// A snapshot as loaded from a checkpoint captured at `at` micros.
    fn persisted(
        tenant: TenantId,
        version: u64,
        at: u64,
        adapters: Vec<LoraAdapter>,
    ) -> Arc<AdapterSnapshot> {
        Arc::new(AdapterSnapshot { tenant, version, adapters, restored_from_micros: Some(at) })
    }

    #[test]
    fn restore_installs_exact_versions_and_raises_the_floor() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(8);
        assert_eq!(reg.current_version(), 0);
        assert!(reg.restore(persisted(3, 41, 100, adapters(&mut rng))));
        assert!(reg.restore(persisted(9, 7, 100, adapters(&mut rng))));
        assert_eq!(reg.version(3), 41, "restored at the persisted version");
        assert_eq!(reg.version(9), 7);
        assert!(reg.current_version() >= 41);
        // every post-restore publish outranks everything restored
        let v = reg.publish(5, adapters(&mut rng));
        assert!(v > 41, "publish after restore allocated {v}");
        // within the SAME checkpoint stamp, a newer version replaces
        assert!(reg.restore(persisted(9, 8, 100, adapters(&mut rng))));
        assert_eq!(reg.version(9), 8);
        // ...and an older one is an idempotent no-op
        assert!(!reg.restore(persisted(9, 7, 100, adapters(&mut rng))));
        assert_eq!(reg.version(9), 8);
    }

    #[test]
    fn restore_never_clobbers_locally_published_adapters() {
        let reg = AdapterRegistry::new();
        let mut rng = Rng::new(9);
        let stale = adapters(&mut rng);
        let stale_marker = stale[0].wa.data[0];
        let live = reg.publish(1, adapters(&mut rng));
        // a checkpoint at the same version must be a no-op...
        assert!(
            !reg.restore(persisted(1, live, 100, stale.clone())),
            "equal version reinstalled"
        );
        let snap = reg.snapshot(1).unwrap();
        assert_eq!(snap.version, live);
        assert_ne!(snap.adapters[0].wa.data[0], stale_marker);
        // ...and so must a checkpoint with a BIGGER version: version
        // numbers reset across restarts (the post-crash-retrain scenario:
        // fresh counter, tenant retrains at v1, operator restores a
        // pre-crash checkpoint claiming v41 — the retrain must survive)
        assert!(!reg.restore(persisted(1, live + 40, 100, stale)));
        let snap = reg.snapshot(1).unwrap();
        assert_eq!(snap.version, live, "live-trained adapters were clobbered");
        assert!(snap.restored_from_micros.is_none());
        // the floor was still raised: the next publish heals the domain
        assert!(reg.publish(1, adapters(&mut rng)) > live + 40);
    }

    #[test]
    fn out_of_order_restores_cannot_resurrect_older_checkpoints() {
        // crash #1: checkpoint A (pre-crash, high versions, EARLY stamp);
        // revive, tenant retrains, checkpoint B (low versions, LATE
        // stamp); crash #2. The operator restores A then B — and B must
        // win despite its smaller raw version. Restoring B then A must
        // ALSO leave B's weights live.
        let mut rng = Rng::new(11);
        let early = adapters(&mut rng);
        let late = adapters(&mut rng);
        let late_marker = late[0].wa.data[0];

        // A (stamp 100, v41) then B (stamp 200, v1)
        let reg = AdapterRegistry::new();
        assert!(reg.restore(persisted(1, 41, 100, early.clone())));
        assert!(reg.restore(persisted(1, 1, 200, late.clone())), "later capture must win");
        let snap = reg.snapshot(1).unwrap();
        assert_eq!((snap.version, snap.adapters[0].wa.data[0]), (1, late_marker));

        // B (stamp 200, v1) then A (stamp 100, v41)
        let reg = AdapterRegistry::new();
        assert!(reg.restore(persisted(1, 1, 200, late)));
        assert!(!reg.restore(persisted(1, 41, 100, early)), "stale checkpoint resurrected");
        let snap = reg.snapshot(1).unwrap();
        assert_eq!((snap.version, snap.adapters[0].wa.data[0]), (1, late_marker));
        // the floor covers BOTH checkpoints either way
        assert!(reg.publish(1, adapters(&mut rng)) > 41);
    }

    #[test]
    fn version_floor_is_monotone() {
        let reg = AdapterRegistry::new();
        reg.raise_version_floor(100);
        assert_eq!(reg.current_version(), 100);
        reg.raise_version_floor(50); // lowering is a no-op
        assert_eq!(reg.current_version(), 100);
        let mut rng = Rng::new(10);
        assert_eq!(reg.publish(1, adapters(&mut rng)), 101);
    }

    #[test]
    fn shard_stats_count_lock_traffic() {
        let reg = AdapterRegistry::with_shards(2);
        let mut rng = Rng::new(7);
        reg.publish(3, adapters(&mut rng));
        reg.snapshot(3);
        let stats = reg.shard_stats();
        let s = stats[reg.shard_of(3)];
        assert!(s.writes >= 1, "{stats:?}");
        assert!(s.reads >= 1, "{stats:?}");
    }
}
