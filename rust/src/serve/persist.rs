//! Durable tenant state — crash-safe registry checkpoints and tenant
//! migration payloads, in the `.s2l` [`TensorBundle`] format.
//!
//! Skip2-LoRA's economics make per-tenant adapters cheap to train but
//! valuable to keep: a tenant's whole personalization is a few KB of
//! weights, so the ENTIRE fleet's state fits in one small file. This
//! module serializes a consistent cut of the sharded
//! [`AdapterRegistry`](crate::serve::registry::AdapterRegistry) —
//! per-tenant weights + publish versions, plus the global version
//! counter — so that a `FleetServer` restart (or a node-to-node tenant
//! migration) never throws trained adapters away.
//!
//! ## File layout (DESIGN.md §9)
//!
//! One `.s2l` bundle containing:
//!
//! * `__manifest__` — `1×14` f32 vector: `[format_version,
//!   n_tenants(4 limbs), next_version(4 limbs), n_layers,
//!   captured_at_micros(4 limbs)]`. `u64` values are encoded as four
//!   16-bit limbs (each exactly representable in f32), so versions and
//!   the capture stamp survive the float container bit-exactly.
//! * per tenant `t{id}.meta` — `1×5`: `[version(4 limbs), n_adapters]`;
//! * per tenant, per layer `t{id}.a{k}.wa` / `t{id}.a{k}.wb` — the
//!   adapter factor matrices (see `model::adapters::write_adapters`).
//!
//! ## Torn-file rejection
//!
//! Validation is belt and braces: the byte layer rejects truncation,
//! trailing bytes, and dimension overflow (`TensorBundle::from_bytes`);
//! this layer then rejects manifest absence, format-version drift,
//! tenant-count mismatch, per-tenant adapter-count mismatch, versions of
//! 0 or above the persisted counter, rank-mismatched factors, and any
//! tensor the manifest does not account for. Every rejection is a typed
//! [`Error`](crate::util::error::Error) — a corrupt checkpoint can never
//! panic the server, and `TensorBundle::save`'s tmp+fsync+rename makes a
//! torn file under the target name impossible in the first place.
//!
//! ## Restore semantics
//!
//! [`RegistryCheckpoint::restore_into`] installs each tenant at its
//! EXACT persisted version via `AdapterRegistry::restore`, skipping
//! tenants the live registry already holds at an equal-or-newer version
//! — or at ANY locally published version: version numbers reset across
//! restarts, so a pre-crash checkpoint can outnumber adapters a tenant
//! just retrained post-crash, and live training always beats checkpoint
//! data. The global version counter is raised to the checkpoint's
//! either way — so the per-tenant version-monotonicity invariant
//! stress-proved in PR 3 holds ACROSS a crash/restore boundary, and
//! every post-restore publish outranks everything persisted.

use std::path::Path;
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::model::adapters::{read_adapters, write_adapters};
use crate::model::io::TensorBundle;
use crate::nn::lora::LoraAdapter;
use crate::serve::registry::{AdapterRegistry, AdapterSnapshot, TenantId};
use crate::util::error::{bail, Context, Result};

/// Checkpoint format version — bump on any layout change so an old
/// binary rejects a new file with a clear error instead of mis-parsing.
pub const FORMAT_VERSION: u64 = 1;

const MANIFEST: &str = "__manifest__";
/// manifest floats: format_version + n_tenants(4) + next_version(4) +
/// n_layers + captured_at_micros(4)
const MANIFEST_LEN: usize = 14;
/// tenant meta floats: version(4) + n_adapters
const META_LEN: usize = 5;

/// Append `x` as four 16-bit limbs, little-endian limb order. Each limb
/// is ≤ 65535 and therefore exactly representable in f32 — the float
/// container carries the u64 bit-exactly.
fn push_u64(out: &mut Vec<f32>, x: u64) {
    for i in 0..4 {
        out.push(((x >> (16 * i)) & 0xFFFF) as f32);
    }
}

/// Decode four 16-bit limbs written by [`push_u64`], rejecting limbs
/// that are not integers in `[0, 65535]` (a torn or hand-edited file).
fn read_u64(limbs: &[f32], what: &str) -> Result<u64> {
    if limbs.len() < 4 {
        bail!("{what}: expected 4 u64 limbs, got {}", limbs.len());
    }
    let mut x = 0u64;
    for (i, &limb) in limbs.iter().take(4).enumerate() {
        if !(limb.is_finite() && limb.fract() == 0.0 && (0.0..=65535.0).contains(&limb)) {
            bail!("{what}: limb {i} is not a 16-bit integer ({limb})");
        }
        x |= (limb as u64) << (16 * i);
    }
    Ok(x)
}

/// Decode a small count stored as one f32 (exact for the values we
/// write; anything non-integral or out of range is a corrupt file).
fn read_count(v: f32, what: &str) -> Result<usize> {
    if !(v.is_finite() && v.fract() == 0.0 && (0.0..=16_777_216.0).contains(&v)) {
        bail!("{what}: not a valid count ({v})");
    }
    Ok(v as usize)  // s2l-lint: allow(cast) reason=f32 has no TryFrom; v is range-validated above
}

/// One tenant's persisted state, wrapping the immutable registry
/// snapshot. At capture time this SHARES the live registry's `Arc` —
/// checkpointing a fleet never deep-copies adapter weights (the only
/// weight copy happens at serialization, into the output bundle). After
/// a load it owns a freshly parsed snapshot flagged `restored`.
#[derive(Clone, Debug)]
pub struct TenantRecord {
    pub snapshot: Arc<AdapterSnapshot>,
}

impl TenantRecord {
    pub fn tenant(&self) -> TenantId {
        self.snapshot.tenant
    }

    /// The publish version the weights were live at.
    pub fn version(&self) -> u64 {
        self.snapshot.version
    }

    /// The adapter weights, one per backbone layer.
    pub fn adapters(&self) -> &[LoraAdapter] {
        &self.snapshot.adapters
    }
}

/// A consistent cut of the whole registry: every record's weights are an
/// immutable published snapshot (never a torn mid-publish view — the
/// registry hands out `Arc`s of complete sets only), and `next_version`
/// is ≥ every record's version by construction.
#[derive(Clone, Debug, Default)]
pub struct RegistryCheckpoint {
    /// the global version counter at capture time
    pub next_version: u64,
    /// wall-clock capture stamp (unix micros). Version numbers reset
    /// across restarts, so THIS is what orders two checkpoints of the
    /// same fleet: restore resolves restored-vs-restored conflicts by
    /// capture stamp, never by raw version (see
    /// [`AdapterRegistry::restore`]).
    pub captured_at_micros: u64,
    /// per-tenant records, sorted by tenant id
    pub tenants: Vec<TenantRecord>,
}

/// Wall-clock unix micros (0 if the clock reads before the epoch —
/// ordering degrades gracefully rather than panicking).
fn now_micros() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map_or(0, |d| d.as_micros() as u64)
}

impl RegistryCheckpoint {
    /// Capture a consistent cut of `reg`. Publishers may race the
    /// capture freely: each included record is an actually-published
    /// immutable snapshot (either the pre-race or post-race version,
    /// never a blend), and the counter is read AFTER the snapshots so it
    /// upper-bounds every captured version.
    pub fn capture(reg: &AdapterRegistry) -> Self {
        let ids = reg.tenants();
        let snaps = reg.snapshot_many(ids.iter().copied());
        // records share the registry's immutable Arcs — capturing a
        // 10^5-tenant fleet moves pointers, not weights
        let mut tenants: Vec<TenantRecord> = snaps
            .into_values()
            .map(|snapshot| TenantRecord { snapshot })
            .collect();
        tenants.sort_unstable_by_key(|r| r.tenant());
        // read the counter LAST: every captured version was allocated
        // from it before we got here, so this load dominates them all
        let next_version = reg.current_version();
        Self { next_version, captured_at_micros: now_micros(), tenants }
    }

    /// Capture a single tenant — the node-to-node migration payload.
    /// `None` if the tenant has nothing published.
    pub fn capture_tenant(reg: &AdapterRegistry, tenant: TenantId) -> Option<Self> {
        let snapshot = reg.snapshot(tenant)?;
        Some(Self {
            next_version: snapshot.version,
            captured_at_micros: now_micros(),
            tenants: vec![TenantRecord { snapshot }],
        })
    }

    /// Reject a checkpoint that would serialize into a file `from_bundle`
    /// itself refuses to load — called by [`RegistryCheckpoint::save`]
    /// (and the server's persist path) so an operator can never write an
    /// unreadable "backup". Today's single rule: every tenant must carry
    /// the same adapter count (one manifest-wide `n_layers`); a raw
    /// registry CAN hold heterogeneous layer counts since `publish` does
    /// not shape-check, but such a fleet is not checkpointable.
    pub fn validate(&self) -> Result<()> {
        let n_layers = self.n_layers();
        for rec in &self.tenants {
            if rec.adapters().len() != n_layers {
                bail!(
                    "tenant {} has {} adapters but tenant {} has {n_layers} — \
                     heterogeneous fleets cannot be checkpointed",
                    rec.tenant(),
                    rec.adapters().len(),
                    self.tenants[0].tenant()
                );
            }
        }
        Ok(())
    }

    /// Total adapter parameters across all records.
    pub fn param_count(&self) -> usize {
        self.tenants
            .iter()
            .map(|r| r.adapters().iter().map(|a| a.param_count()).sum::<usize>())
            .sum()
    }

    /// Skip-adapter layer count of the first record (0 for an empty
    /// checkpoint). All records carrying the same count is enforced at
    /// save ([`RegistryCheckpoint::validate`]) and at load (manifest
    /// validation) — not at capture, since a raw registry can hold
    /// heterogeneous fleets.
    pub fn n_layers(&self) -> usize {
        self.tenants.first().map_or(0, |r| r.adapters().len())
    }

    pub fn to_bundle(&self) -> TensorBundle {
        let mut bundle = TensorBundle::default();
        let mut manifest = Vec::with_capacity(MANIFEST_LEN);
        manifest.push(FORMAT_VERSION as f32);
        push_u64(&mut manifest, self.tenants.len() as u64);
        push_u64(&mut manifest, self.next_version);
        manifest.push(self.n_layers() as f32);
        push_u64(&mut manifest, self.captured_at_micros);
        bundle.insert_vec(MANIFEST, &manifest);
        for rec in &self.tenants {
            let mut meta = Vec::with_capacity(META_LEN);
            push_u64(&mut meta, rec.version());
            meta.push(rec.adapters().len() as f32);
            bundle.insert_vec(&format!("t{}.meta", rec.tenant()), &meta);
            write_adapters(&mut bundle, &format!("t{}.", rec.tenant()), rec.adapters());
        }
        bundle
    }

    /// Parse and FULLY validate a bundle as a registry checkpoint. Any
    /// inconsistency — missing/short manifest, wrong format version,
    /// tenant or adapter counts that disagree with the manifest, corrupt
    /// versions, rank-mismatched factors, unaccounted-for tensors — is a
    /// typed error, never a panic.
    pub fn from_bundle(bundle: &TensorBundle) -> Result<Self> {
        let manifest = bundle
            .get_vec(MANIFEST)
            .context("not a registry checkpoint: no __manifest__ tensor")?;
        if manifest.len() != MANIFEST_LEN {
            bail!(
                "corrupt manifest: {} floats, expected {MANIFEST_LEN}",
                manifest.len()
            );
        }
        let fmt = read_count(manifest[0], "manifest format version")? as u64;
        if fmt != FORMAT_VERSION {
            bail!("unsupported checkpoint format v{fmt} (this build reads v{FORMAT_VERSION})");
        }
        let n_tenants_u64 = read_u64(&manifest[1..5], "manifest tenant count")?;
        let n_tenants = usize::try_from(n_tenants_u64)
            .with_context(|| format!("tenant count {n_tenants_u64} does not fit in usize"))?;
        let next_version = read_u64(&manifest[5..9], "manifest next_version")?;
        let n_layers = read_count(manifest[9], "manifest n_layers")?;
        let captured_at_micros = read_u64(&manifest[10..14], "manifest capture stamp")?;

        // cross-check the declared counts against the ACTUAL tensor count
        // BEFORE believing either of them: manifest + per-tenant meta + 2
        // factor tensors per adapter. This both rejects stray/missing
        // tensors and keeps an adversarial count (e.g. 2^62 tenants in a
        // 100-byte file) from ever reaching an allocation — a corrupt
        // checkpoint must error, never panic or OOM.
        let expected = n_tenants
            .checked_mul(1 + 2 * n_layers)
            .and_then(|t| t.checked_add(1))
            .with_context(|| format!("manifest declares impossible tenant count {n_tenants}"))?;
        if bundle.tensors.len() != expected {
            bail!(
                "checkpoint has {} tensors, expected {expected} for {n_tenants} tenants x \
                 {n_layers} layers (torn or tampered checkpoint)",
                bundle.tensors.len()
            );
        }

        // collect the declared tenants from their meta tensors; the count
        // check above bounds n_tenants by the real tensor count
        let mut tenants = Vec::with_capacity(n_tenants);
        for name in bundle.tensors.keys() {
            let meta_name = name.strip_prefix('t').and_then(|s| s.strip_suffix(".meta"));
            let Some(id_str) = meta_name else {
                continue;
            };
            let tenant: TenantId = id_str
                .parse()
                .with_context(|| format!("corrupt tenant id in tensor name '{name}'"))?;
            // the id must be CANONICAL: "t05.meta" and "t+5.meta" both
            // parse to 5, which would let a tampered file smuggle in
            // duplicate tenant records (and unvalidated filler tensors
            // under the non-canonical prefix) while balancing the counts
            if *name != format!("t{tenant}.meta") {
                bail!("non-canonical tenant tensor name '{name}' (tampered checkpoint?)");
            }
            // s2l-lint: allow(panic) reason=key enumerated from this very bundle above
            let meta = bundle.get_vec(name).expect("key comes from this bundle");
            if meta.len() != META_LEN {
                bail!("tenant {tenant}: corrupt meta ({} floats)", meta.len());
            }
            let version = read_u64(&meta[..4], "tenant version")?;
            if version == 0 || version > next_version {
                bail!(
                    "tenant {tenant}: version {version} impossible under \
                     persisted counter {next_version} (torn checkpoint?)"
                );
            }
            let n_adapters = read_count(meta[4], "tenant adapter count")?;
            if n_adapters != n_layers {
                bail!(
                    "tenant {tenant}: {n_adapters} adapters, manifest says {n_layers} per tenant"
                );
            }
            let adapters = read_adapters(bundle, &format!("t{tenant}."), n_layers)
                .with_context(|| format!("tenant {tenant}"))?;
            tenants.push(TenantRecord {
                snapshot: Arc::new(AdapterSnapshot {
                    tenant,
                    version,
                    adapters,
                    restored_from_micros: Some(captured_at_micros),
                }),
            });
        }
        if tenants.len() != n_tenants {
            bail!(
                "checkpoint holds {} tenants, manifest declares {n_tenants} (torn checkpoint?)",
                tenants.len()
            );
        }
        tenants.sort_unstable_by_key(|r| r.tenant());
        Ok(Self { next_version, captured_at_micros, tenants })
    }

    /// Serialize to `.s2l` bytes (the migration wire payload).
    pub fn to_bytes(&self) -> Vec<u8> {
        self.to_bundle().to_bytes()
    }

    /// Parse + validate `.s2l` bytes. See [`RegistryCheckpoint::from_bundle`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        Self::from_bundle(&TensorBundle::from_bytes(bytes)?)
    }

    /// Atomically persist to `path` (tmp + fsync + rename — a crash
    /// mid-save leaves the previous checkpoint intact, never a torn
    /// one). Validates first: an unloadable checkpoint is refused at
    /// save time, not discovered at restore time.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        self.to_bundle()
            .save(path)
            .with_context(|| format!("save registry checkpoint {}", path.display()))
    }

    /// Load + fully validate the checkpoint at `path`.
    pub fn load(path: &Path) -> Result<Self> {
        Self::from_bundle(
            &TensorBundle::load(path)
                .with_context(|| format!("load registry checkpoint {}", path.display()))?,
        )
    }

    /// Install this checkpoint into `reg`: each tenant at its EXACT
    /// persisted version. A tenant is skipped when the live registry
    /// already holds an equal-or-newer version OR a locally published
    /// snapshot (version numbers reset across restarts, so adapters
    /// trained after a crash are never clobbered by a pre-crash
    /// checkpoint — see [`AdapterRegistry::restore`]). The global counter
    /// is raised to the checkpoint's regardless, so every post-restore
    /// publish outranks everything persisted. Returns the number of
    /// tenants actually installed. Installation moves `Arc`s — no weight
    /// copies.
    pub fn restore_into(&self, reg: &AdapterRegistry) -> usize {
        // floor first: even if every per-tenant install is superseded,
        // future allocations must exceed the persisted counter
        reg.raise_version_floor(self.next_version);
        self.tenants
            .iter()
            .filter(|rec| reg.restore(Arc::clone(&rec.snapshot)))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;
    use crate::util::rng::Rng;

    fn adapters(rng: &mut Rng) -> Vec<LoraAdapter> {
        (0..3)
            .map(|k| {
                let n_in = [8, 12, 12][k];
                let mut ad = LoraAdapter::new(rng, n_in, 2, 3);
                for v in ad.wb.data.iter_mut() {
                    *v = rng.normal();
                }
                ad
            })
            .collect()
    }

    fn populated(rng: &mut Rng, n: u64) -> AdapterRegistry {
        let reg = AdapterRegistry::with_shards(4);
        for t in 0..n {
            reg.publish(t * 7 + 1, adapters(rng));
        }
        reg
    }

    #[test]
    fn u64_limbs_are_bit_exact_at_the_extremes() {
        for x in [0u64, 1, 65535, 65536, u32::MAX as u64, 1 << 40, u64::MAX, u64::MAX - 1] {
            let mut v = Vec::new();
            push_u64(&mut v, x);
            assert_eq!(read_u64(&v, "probe").unwrap(), x, "{x} must roundtrip");
        }
        // corrupt limbs are typed errors
        assert!(read_u64(&[0.5, 0.0, 0.0, 0.0], "p").is_err());
        assert!(read_u64(&[-1.0, 0.0, 0.0, 0.0], "p").is_err());
        assert!(read_u64(&[65536.0, 0.0, 0.0, 0.0], "p").is_err());
        assert!(read_u64(&[f32::NAN, 0.0, 0.0, 0.0], "p").is_err());
        assert!(read_u64(&[0.0, 0.0], "p").is_err());
    }

    #[test]
    fn checkpoint_roundtrips_bit_identical_through_bytes() {
        let mut rng = Rng::new(1);
        let reg = populated(&mut rng, 9);
        let ck = RegistryCheckpoint::capture(&reg);
        assert_eq!(ck.tenants.len(), 9);
        assert_eq!(ck.n_layers(), 3);
        assert!(ck.next_version >= ck.tenants.iter().map(|r| r.version()).max().unwrap());

        let back = RegistryCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.next_version, ck.next_version);
        assert_eq!(back.tenants.len(), ck.tenants.len());
        assert_eq!(back.captured_at_micros, ck.captured_at_micros, "capture stamp survives");
        for (a, b) in ck.tenants.iter().zip(&back.tenants) {
            assert_eq!(a.tenant(), b.tenant());
            assert_eq!(a.version(), b.version());
            assert_eq!(
                b.snapshot.restored_from_micros,
                Some(ck.captured_at_micros),
                "loaded records must carry the checkpoint's capture stamp"
            );
            for (x, y) in a.adapters().iter().zip(b.adapters()) {
                assert_eq!(x.wa, y.wa, "weights must survive bit-identical");
                assert_eq!(x.wb, y.wb);
            }
        }
    }

    #[test]
    fn restore_reproduces_the_registry() {
        let mut rng = Rng::new(2);
        let reg = populated(&mut rng, 6);
        // bump one tenant twice so versions are not all 1..n
        reg.publish(8, adapters(&mut rng));
        let ck = RegistryCheckpoint::capture(&reg);

        let fresh = AdapterRegistry::with_shards(16); // different shard count: must not matter
        assert_eq!(ck.restore_into(&fresh), ck.tenants.len());
        assert_eq!(fresh.tenant_count(), reg.tenant_count());
        for rec in &ck.tenants {
            let snap = fresh.snapshot(rec.tenant()).unwrap();
            assert_eq!(snap.version, rec.version(), "exact persisted version");
            for (x, y) in rec.adapters().iter().zip(&snap.adapters) {
                assert_eq!(x.wa, y.wa);
                assert_eq!(x.wb, y.wb);
            }
        }
        // post-restore publishes outrank everything persisted
        let v = fresh.publish(999, adapters(&mut rng));
        assert!(v > ck.next_version);
        // restoring AGAIN is a no-op (idempotent)
        assert_eq!(ck.restore_into(&fresh), 0);
    }

    #[test]
    fn heterogeneous_fleets_are_refused_at_save_time() {
        // `AdapterRegistry::publish` does not shape-check, so a raw
        // registry CAN hold tenants with differing adapter counts — but
        // such a fleet would serialize into a file `from_bundle` refuses
        // to load (one manifest-wide n_layers). The save path must catch
        // that up front instead of writing an unreadable "backup".
        let mut rng = Rng::new(7);
        let reg = AdapterRegistry::new();
        reg.publish(1, adapters(&mut rng));
        let mut short = adapters(&mut rng);
        short.truncate(2);
        reg.publish(2, short);
        let ck = RegistryCheckpoint::capture(&reg);
        let dir = std::env::temp_dir().join("s2l_persist_hetero");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.s2l");
        let e = ck.save(&path).unwrap_err();
        assert!(e.to_string().contains("heterogeneous"), "{e}");
        assert!(!path.exists(), "unloadable checkpoint reached disk");
    }

    #[test]
    fn empty_checkpoint_is_valid() {
        let reg = AdapterRegistry::new();
        let ck = RegistryCheckpoint::capture(&reg);
        assert_eq!(ck.tenants.len(), 0);
        let back = RegistryCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.tenants.len(), 0);
        assert_eq!(back.restore_into(&AdapterRegistry::new()), 0);
    }

    #[test]
    fn single_tenant_capture_is_the_migration_payload() {
        let mut rng = Rng::new(3);
        let reg = populated(&mut rng, 4);
        assert!(RegistryCheckpoint::capture_tenant(&reg, 9999).is_none());
        let ck = RegistryCheckpoint::capture_tenant(&reg, 8).unwrap();
        assert_eq!(ck.tenants.len(), 1);
        assert_eq!(ck.tenants[0].tenant(), 8);
        let back = RegistryCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.tenants[0].version(), ck.tenants[0].version());
    }

    #[test]
    fn torn_and_tampered_checkpoints_are_typed_errors() {
        let mut rng = Rng::new(4);
        let reg = populated(&mut rng, 5);
        let ck = RegistryCheckpoint::capture(&reg);
        let bytes = ck.to_bytes();

        // every torn prefix fails at SOME validation layer, never panics
        for frac in [0usize, 1, 7, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                RegistryCheckpoint::from_bytes(&bytes[..frac]).is_err(),
                "torn prefix {frac}/{} must be rejected",
                bytes.len()
            );
        }

        // a valid TensorBundle that is NOT a checkpoint
        let mut not_ck = TensorBundle::default();
        not_ck.insert_vec("w1", &[1.0, 2.0]);
        let e = RegistryCheckpoint::from_bundle(&not_ck).unwrap_err();
        assert!(e.to_string().contains("manifest"), "{e}");

        // manifest declaring more tenants than the file carries
        let mut bundle = ck.to_bundle();
        let mut manifest = bundle.get_vec(MANIFEST).unwrap();
        manifest[1] += 1.0; // tenant count low limb
        bundle.insert_vec(MANIFEST, &manifest);
        let e = RegistryCheckpoint::from_bundle(&bundle).unwrap_err();
        assert!(e.to_string().contains("tensors, expected"), "{e}");

        // an ADVERSARIAL tenant count (2^62 in a tiny file) must be a
        // typed error before any allocation — never a capacity panic/OOM
        let mut bundle = ck.to_bundle();
        let mut manifest = bundle.get_vec(MANIFEST).unwrap();
        (manifest[1], manifest[2], manifest[3], manifest[4]) = (0.0, 0.0, 0.0, 16384.0);
        bundle.insert_vec(MANIFEST, &manifest);
        let e = RegistryCheckpoint::from_bundle(&bundle).unwrap_err();
        assert!(e.to_string().contains("impossible tenant count"), "{e}");

        // a stray tensor the manifest cannot account for
        let mut bundle = ck.to_bundle();
        bundle.insert_vec("stowaway", &[0.0]);
        let e = RegistryCheckpoint::from_bundle(&bundle).unwrap_err();
        assert!(e.to_string().contains("tensors, expected"), "{e}");

        // a tenant version above the persisted counter (a torn cut)
        let mut bundle = ck.to_bundle();
        let t0 = ck.tenants[0].tenant();
        let mut meta = bundle.get_vec(&format!("t{t0}.meta")).unwrap();
        meta[3] = 65535.0; // version high limb -> astronomically large
        bundle.insert_vec(&format!("t{t0}.meta"), &meta);
        let e = RegistryCheckpoint::from_bundle(&bundle).unwrap_err();
        assert!(e.to_string().contains("impossible"), "{e}");

        // a future format version
        let mut bundle = ck.to_bundle();
        let mut manifest = bundle.get_vec(MANIFEST).unwrap();
        manifest[0] = (FORMAT_VERSION + 1) as f32;
        bundle.insert_vec(MANIFEST, &manifest);
        let e = RegistryCheckpoint::from_bundle(&bundle).unwrap_err();
        assert!(e.to_string().contains("unsupported"), "{e}");

        // rank-torn factor matrices
        let mut bundle = ck.to_bundle();
        bundle.insert(&format!("t{t0}.a0.wb"), Mat::zeros(5, 3));
        let e = RegistryCheckpoint::from_bundle(&bundle).unwrap_err();
        assert!(e.to_string().contains("rank mismatch"), "{e}");

        // non-canonical tenant ids ("t08" parses to 8) could smuggle in
        // duplicate tenant records plus unvalidated filler tensors while
        // balancing every count — rejected by the canonical-name check
        let mut bundle = ck.to_bundle();
        let moved: Vec<String> = bundle
            .tensors
            .keys()
            .filter(|k| k.starts_with(&format!("t{t0}.")))
            .cloned()
            .collect();
        for old in moved {
            let tensor = bundle.tensors.remove(&old).unwrap();
            let renamed = old.replacen(&format!("t{t0}."), &format!("t0{t0}."), 1);
            bundle.tensors.insert(renamed, tensor);
        }
        let e = RegistryCheckpoint::from_bundle(&bundle).unwrap_err();
        assert!(e.to_string().contains("non-canonical"), "{e}");
    }

    #[test]
    fn save_load_through_disk_is_atomic_and_clean() {
        let mut rng = Rng::new(5);
        let reg = populated(&mut rng, 3);
        let ck = RegistryCheckpoint::capture(&reg);
        let dir = std::env::temp_dir().join("s2l_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.s2l");
        ck.save(&path).unwrap();
        // overwrite with a GROWN registry: load must see old-complete or
        // new-complete, and after save returns, the new one
        reg.publish(500, adapters(&mut rng));
        RegistryCheckpoint::capture(&reg).save(&path).unwrap();
        let back = RegistryCheckpoint::load(&path).unwrap();
        assert_eq!(back.tenants.len(), 4);
        // a torn file ON DISK is rejected, not panicked on
        let bytes = std::fs::read(&path).unwrap();
        let torn = dir.join("torn.s2l");
        std::fs::write(&torn, &bytes[..bytes.len() / 2]).unwrap();
        assert!(RegistryCheckpoint::load(&torn).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&torn).ok();
    }
}
