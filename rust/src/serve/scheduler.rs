//! Fixed worker pool with per-worker queues and work stealing.
//!
//! Fine-tune jobs are coarse (tens of milliseconds to seconds), so the
//! scheduler optimizes for simplicity and locality rather than
//! nanosecond-scale stealing: each worker owns a deque, `submit`
//! round-robins across owners, owners pop from the front of their own
//! queue, and an idle worker steals from the BACK of a sibling's queue
//! (oldest-first stealing, the classic deque discipline — cf. the
//! FlatPool/work-stealing designs this module is modeled on). Everything
//! is std-only: `Mutex<VecDeque>` + atomics, no crossbeam.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queues: Vec<Mutex<VecDeque<Job>>>,
    stop: AtomicBool,
    submitted: AtomicU64,
    executed: AtomicU64,
    steals: AtomicU64,
    panics: AtomicU64,
}

/// Run one job with panic isolation: a panicking job is counted and
/// swallowed so the worker thread survives and `executed` still
/// advances (otherwise `pending()` would never reach zero and
/// `wait_idle` would hang forever).
fn run_job(sh: &Shared, job: Job) {
    if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
        sh.panics.fetch_add(1, Ordering::SeqCst);
    }
    sh.executed.fetch_add(1, Ordering::SeqCst);
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.handles.len())
            .finish()
    }
}

/// Counters snapshot for diagnostics and tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub workers: usize,
    pub submitted: u64,
    pub executed: u64,
    pub steals: u64,
    /// jobs that panicked (isolated; the worker thread survives)
    pub panics: u64,
}

pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
    rr: AtomicUsize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "worker pool needs at least one worker");
        let shared = Arc::new(Shared {
            queues: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            stop: AtomicBool::new(false),
            submitted: AtomicU64::new(0),
            executed: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            panics: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&sh, i))
                    .expect("spawn serve worker")
            })
            .collect();
        Self {
            shared,
            handles,
            rr: AtomicUsize::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Enqueue a job on the next worker round-robin.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let n = self.shared.queues.len();
        let i = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        self.submit_to(i, job);
    }

    /// Enqueue a job on a specific worker's queue (tests use this to force
    /// imbalance and observe stealing).
    pub fn submit_to(&self, worker: usize, job: impl FnOnce() + Send + 'static) {
        self.shared.submitted.fetch_add(1, Ordering::SeqCst);
        self.shared.queues[worker]
            .lock()
            .expect("worker queue poisoned")
            .push_back(Box::new(job));
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> u64 {
        let s = self.shared.submitted.load(Ordering::SeqCst);
        let e = self.shared.executed.load(Ordering::SeqCst);
        s.saturating_sub(e)
    }

    /// Per-worker queue depths (jobs waiting, not counting the one a
    /// worker may be running) — the backlog-skew diagnostic that pairs
    /// with the registry's per-shard `ShardStats`.
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shared
            .queues
            .iter()
            .map(|q| q.lock().expect("worker queue poisoned").len())
            .collect()
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::sleep(Duration::from_micros(300));
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.handles.len(),
            submitted: self.shared.submitted.load(Ordering::SeqCst),
            executed: self.shared.executed.load(Ordering::SeqCst),
            steals: self.shared.steals.load(Ordering::SeqCst),
            panics: self.shared.panics.load(Ordering::SeqCst),
        }
    }

    /// Stop the workers (each drains the queues before exiting) and join
    /// them. Returns the final counters.
    pub fn shutdown(mut self) -> PoolStats {
        self.shared.stop.store(true, Ordering::SeqCst);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.stats()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: &Shared, me: usize) {
    let n = sh.queues.len();
    loop {
        // own queue first: FIFO from the front
        let local = sh.queues[me]
            .lock()
            .expect("worker queue poisoned")
            .pop_front();
        if let Some(job) = local {
            run_job(sh, job);
            continue;
        }
        // idle: steal the oldest job from a sibling's back
        let mut stolen = None;
        for off in 1..n {
            let victim = (me + off) % n;
            let job = sh.queues[victim]
                .lock()
                .expect("worker queue poisoned")
                .pop_back();
            if job.is_some() {
                stolen = job;
                break;
            }
        }
        if let Some(job) = stolen {
            sh.steals.fetch_add(1, Ordering::SeqCst);
            run_job(sh, job);
            continue;
        }
        // every queue observed empty this pass: exit if stopping
        if sh.stop.load(Ordering::SeqCst) {
            return;
        }
        thread::sleep(Duration::from_micros(200));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_jobs_execute() {
        let pool = WorkerPool::new(4);
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..200 {
            let h = Arc::clone(&hits);
            pool.submit(move || {
                h.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 200);
        let stats = pool.shutdown();
        assert_eq!(stats.executed, 200);
        assert_eq!(stats.submitted, 200);
    }

    #[test]
    fn imbalanced_load_is_stolen() {
        let pool = WorkerPool::new(4);
        let hits = Arc::new(AtomicU64::new(0));
        // everything lands on worker 0's queue; 1..3 must steal to help
        for _ in 0..48 {
            let h = Arc::clone(&hits);
            pool.submit_to(0, move || {
                thread::sleep(Duration::from_millis(1));
                h.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 48);
        let stats = pool.shutdown();
        assert!(stats.steals > 0, "idle workers never stole: {stats:?}");
    }

    #[test]
    fn shutdown_drains_outstanding_jobs() {
        let pool = WorkerPool::new(2);
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..32 {
            let h = Arc::clone(&hits);
            pool.submit(move || {
                h.fetch_add(1, Ordering::SeqCst);
            });
        }
        // no wait_idle: workers drain queues before exiting on stop
        let stats = pool.shutdown();
        assert_eq!(stats.executed, 32);
        assert_eq!(hits.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn panicking_jobs_are_isolated() {
        let pool = WorkerPool::new(2);
        let hits = Arc::new(AtomicU64::new(0));
        for i in 0..20 {
            let h = Arc::clone(&hits);
            pool.submit(move || {
                if i % 5 == 0 {
                    panic!("job {i} exploded");
                }
                h.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle(); // would hang forever if panics lost `executed`
        assert_eq!(hits.load(Ordering::SeqCst), 16);
        let stats = pool.shutdown();
        assert_eq!(stats.executed, 20, "panicked jobs still count as executed");
        assert_eq!(stats.panics, 4);
    }

    #[test]
    fn pending_reaches_zero() {
        let pool = WorkerPool::new(2);
        for _ in 0..10 {
            pool.submit(|| {});
        }
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn queue_depths_report_per_worker_backlog() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.queue_depths(), vec![0, 0, 0]);
        pool.wait_idle();
        assert_eq!(pool.queue_depths().iter().sum::<usize>(), 0);
    }
}
