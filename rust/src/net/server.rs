//! [`NodeServer`] — the TCP serving edge for one [`FleetServer`].
//!
//! A `NodeServer` owns the fleet server behind an `Arc<Mutex>`, binds a
//! listener (port 0 works: the kernel picks, [`NodeServer::addr`] tells),
//! and answers `skip2lora/wire` frames from concurrent connections.
//! Every connection must open with a valid `Hello` handshake; anything
//! else — wrong magic, wrong version, bad auth token, over the
//! connection cap, malformed frame — gets a typed response
//! ([`WireResponse::Error`] / [`WireResponse::Unauthorized`] /
//! [`WireResponse::Busy`]), never a panic or a silent close.
//!
//! Concurrency model: the accept loop and each connection run on plain
//! `std::thread`s, all checking one shared stop flag through short read
//! timeouts — no async runtime, no dependencies. Requests serialize
//! through the `Mutex`, which matches the serving plane's design: the
//! expensive work (backbone forwards, fine-tunes) already happens on the
//! batcher/worker-pool threads inside `FleetServer`; the lock only
//! covers enqueue/pump bookkeeping. Crucially the PUMP CLOCK stays with
//! whichever client drives `Pump`/`PumpDrain`, so a driver controls
//! batching determinism over the wire exactly as it would in-process.
//!
//! Unattended-edge hardening ([`NodeServerConfig`], DESIGN.md §15):
//!
//! - `auth_token`: optional shared secret checked on the `Hello` BEFORE
//!   any other verb is served; a wrong or missing token is answered with
//!   [`WireResponse::Unauthorized`] and the connection closed.
//! - `max_connections`: a hard cap on live connections. Over-limit peers
//!   still get a full handshake answer — [`WireResponse::Busy`] — so a
//!   router can tell "node saturated" from "node dead".
//! - `idle_timeout`: a connection that sits between frames longer than
//!   this is reaped (clean close), so abandoned sockets cannot pin
//!   threads forever. Mid-frame reads are NOT idle — a slow sender
//!   keeps its connection.
//! - at-most-once admissions: `Predict`/`Feedback` frames carrying a
//!   nonzero `(client_id, req_id)` pair record their admission response
//!   in a bounded dedupe log; a retry of the same pair replays the
//!   recorded response instead of enqueuing twice. This is what makes a
//!   client retry after an AMBIGUOUS outcome (response lost mid-frame)
//!   safe — the books still balance.
//!
//! [`NodeServer::shutdown`] stops the accept loop, joins every
//! connection thread, and hands the inner [`FleetServer`] back — this is
//! how the multi-node tests "kill" a node and how a decommissioned
//! node's state can be inspected after its tenants have migrated away.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::serve::server::{FleetServer, Request, Response};
use crate::util::error::{anyhow, Context, Result};

use super::wire::{
    decode_request, write_response, WireRequest, WireResponse, MAX_FRAME_BYTES, WIRE_VERSION,
};

/// How long a blocked read waits before re-checking the stop flag.
const POLL: Duration = Duration::from_millis(25);

/// Bound on the admission-dedupe log: old `(client_id, req_id)` entries
/// are evicted FIFO past this, which is fine — dedupe only needs to
/// cover the retry window of an in-flight request, not all history.
const DEDUPE_CAP: usize = 4096;

/// Serving-edge hardening knobs (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeServerConfig {
    /// shared secret a client's `Hello` must present; `None` = open
    pub auth_token: Option<String>,
    /// live-connection cap; 0 = unlimited
    pub max_connections: usize,
    /// reap a connection idle between frames this long; zero = never
    pub idle_timeout: Duration,
}

impl Default for NodeServerConfig {
    fn default() -> Self {
        Self {
            auth_token: None,
            max_connections: 64,
            idle_timeout: Duration::ZERO,
        }
    }
}

/// Bounded `(client_id, req_id) → admission response` replay log — the
/// server half of the at-most-once contract.
struct AdmissionLog {
    map: HashMap<(u64, u64), WireResponse>,
    order: VecDeque<(u64, u64)>,
}

impl AdmissionLog {
    fn new() -> Self {
        Self {
            map: HashMap::new(),
            order: VecDeque::new(),
        }
    }

    fn get(&self, key: (u64, u64)) -> Option<WireResponse> {
        self.map.get(&key).cloned()
    }

    fn put(&mut self, key: (u64, u64), resp: WireResponse) {
        if self.map.insert(key, resp).is_none() {
            self.order.push_back(key);
        }
        while self.order.len() > DEDUPE_CAP {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
    }
}

/// One fleet-server node listening on a TCP address.
pub struct NodeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    server: Arc<Mutex<FleetServer>>,
    accept_thread: JoinHandle<()>,
}

impl NodeServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `server` with the default [`NodeServerConfig`].
    pub fn spawn(server: FleetServer, addr: &str) -> Result<Self> {
        Self::spawn_with(server, addr, NodeServerConfig::default())
    }

    /// [`NodeServer::spawn`] with explicit auth/cap/idle hardening.
    pub fn spawn_with(server: FleetServer, addr: &str, cfg: NodeServerConfig) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind node listener on {addr}"))?;
        let addr = listener
            .local_addr()
            .context("read bound listener address")?;
        listener
            .set_nonblocking(true)
            .context("set listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let server = Arc::new(Mutex::new(server));
        let accept_thread = {
            let stop = Arc::clone(&stop);
            let server = Arc::clone(&server);
            thread::spawn(move || accept_loop(listener, stop, server, cfg))
        };
        Ok(Self {
            addr,
            stop,
            server,
            accept_thread,
        })
    }

    /// The actual bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Run `f` against the inner server directly — for local drivers and
    /// tests that want in-process access (oracle comparisons) while the
    /// network edge is live. Serializes with wire requests via the same
    /// mutex, so it cannot observe a half-applied frame.
    pub fn with_server<R>(&self, f: impl FnOnce(&mut FleetServer) -> R) -> R {
        // s2l-lint: allow(panic) reason=poisoned mutex means a peer thread crashed; propagating is policy
        f(&mut self.server.lock().expect("node server mutex poisoned"))
    }

    /// Stop accepting, join every connection thread, and return the
    /// inner [`FleetServer`] (adapters, metrics and all). In-flight
    /// frames finish first — the stop flag is only checked between
    /// frames — so no response is ever torn mid-write.
    pub fn shutdown(self) -> FleetServer {
        let NodeServer {
            stop,
            server,
            accept_thread,
            ..
        } = self;
        stop.store(true, Ordering::SeqCst);
        let _ = accept_thread.join();
        // the accept loop joined every connection thread before exiting,
        // so ours is the last strong reference
        match Arc::try_unwrap(server) {
            Ok(m) => m.into_inner().expect("node server mutex poisoned"),  // s2l-lint: allow(panic) reason=poisoned mutex means a peer thread crashed; propagating is policy
            Err(_) => unreachable!("all connection threads were joined"),  // s2l-lint: allow(panic) reason=Arc::try_unwrap cannot fail after join()
        }
    }
}

/// Everything one connection thread needs beyond its stream.
struct ConnShared {
    stop: Arc<AtomicBool>,
    server: Arc<Mutex<FleetServer>>,
    dedupe: Arc<Mutex<AdmissionLog>>,
    live: Arc<AtomicUsize>,
    cfg: Arc<NodeServerConfig>,
}

fn accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    server: Arc<Mutex<FleetServer>>,
    cfg: NodeServerConfig,
) {
    let cfg = Arc::new(cfg);
    let dedupe = Arc::new(Mutex::new(AdmissionLog::new()));
    let live = Arc::new(AtomicUsize::new(0));
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(true);
                let _ = stream.set_read_timeout(Some(POLL));
                live.fetch_add(1, Ordering::SeqCst);
                let shared = ConnShared {
                    stop: Arc::clone(&stop),
                    server: Arc::clone(&server),
                    dedupe: Arc::clone(&dedupe),
                    live: Arc::clone(&live),
                    cfg: Arc::clone(&cfg),
                };
                conns.push(thread::spawn(move || {
                    let _ = serve_connection(stream, &shared);
                    shared.live.fetch_sub(1, Ordering::SeqCst);
                }));
                // joining finished threads here keeps the handle list
                // (and thread count) proportional to LIVE connections,
                // not to connection history
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => thread::sleep(Duration::from_millis(1)),
            // a failed accept (e.g. listener torn down) only ends the loop
            Err(_) => break,
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

/// Read one length-prefixed frame, waking every [`POLL`] to honor the
/// stop flag. `Ok(None)` means clean EOF before a frame started, stop,
/// or `idle_polls` expired while no frame was in progress (the reap
/// path). A connection dying MID-frame is an error, like a torn file.
fn read_frame_stoppable(
    stream: &mut TcpStream,
    stop: &AtomicBool,
    idle_polls: u64,
) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    let mut idle = 0u64;
    while got < 4 {
        if stop.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match stream.read(&mut len_buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(anyhow!("connection closed mid length-prefix"));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                // only a connection with NO frame in progress is idle
                if got == 0 && idle_polls > 0 {
                    idle += 1;
                    if idle >= idle_polls {
                        return Ok(None);
                    }
                }
            }
            Err(e) => return Err(anyhow!("read frame length: {e}")),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 {
        return Err(anyhow!("zero-length wire frame"));
    }
    if len > MAX_FRAME_BYTES {
        return Err(anyhow!(
            "announced frame of {len} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})"
        ));
    }
    let mut body = vec![0u8; len];
    let mut got = 0usize;
    while got < len {
        match stream.read(&mut body[got..]) {
            Ok(0) => return Err(anyhow!("connection closed mid frame body")),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
            Err(e) => return Err(anyhow!("read frame body: {e}")),
        }
    }
    Ok(Some(body))
}

/// Idle budget in poll intervals (rounded up); 0 = never reap.
fn idle_poll_budget(idle_timeout: Duration) -> u64 {
    if idle_timeout.is_zero() {
        return 0;
    }
    let nanos = idle_timeout.as_nanos();
    let poll = POLL.as_nanos().max(1);
    u64::try_from(nanos.div_ceil(poll)).unwrap_or(u64::MAX).max(1)  // s2l-lint: allow(panic) reason=unwrap_or cannot panic
}

fn serve_connection(mut stream: TcpStream, shared: &ConnShared) -> Result<()> {
    let idle_polls = idle_poll_budget(shared.cfg.idle_timeout);
    // handshake: the FIRST frame must be a well-formed Hello at our
    // version, carrying the right token, while under the connection cap
    // — anything else is answered with a typed response and the
    // connection is closed
    let first = match read_frame_stoppable(&mut stream, &shared.stop, idle_polls)? {
        Some(body) => body,
        None => return Ok(()),
    };
    let client_id = match decode_request(&first) {
        Ok(WireRequest::Hello {
            version,
            token,
            client_id,
        }) => {
            if version != WIRE_VERSION {
                write_response(
                    &mut stream,
                    &WireResponse::Error {
                        msg: format!(
                            "wire version mismatch: client v{version}, server v{WIRE_VERSION}"
                        ),
                    },
                )?;
                return Ok(());
            }
            // auth precedes everything else — an unauthorized peer
            // learns nothing, not even whether the node is saturated
            if shared.cfg.auth_token.is_some() && token != shared.cfg.auth_token {
                write_response(&mut stream, &WireResponse::Unauthorized)?;
                return Ok(());
            }
            let cap = shared.cfg.max_connections;
            if cap > 0 && shared.live.load(Ordering::SeqCst) > cap {
                write_response(
                    &mut stream,
                    &WireResponse::Busy { limit: cap as u64 },  // s2l-lint: allow(cast) reason=usize config bound to u64 widening
                )?;
                return Ok(());
            }
            write_response(
                &mut stream,
                &WireResponse::HelloOk {
                    version: WIRE_VERSION,
                },
            )?;
            client_id
        }
        Ok(other) => {
            write_response(
                &mut stream,
                &WireResponse::Error {
                    msg: format!("expected Hello as first frame, got {other:?}"),
                },
            )?;
            return Ok(());
        }
        Err(e) => {
            write_response(&mut stream, &WireResponse::Error { msg: e.to_string() })?;
            return Ok(());
        }
    };

    loop {
        let body = match read_frame_stoppable(&mut stream, &shared.stop, idle_polls)? {
            Some(body) => body,
            None => return Ok(()),
        };
        let resp = match decode_request(&body) {
            // the framing survived, only this frame's content is bad —
            // answer with a typed error and keep the connection
            Err(e) => WireResponse::Error { msg: e.to_string() },
            Ok(WireRequest::Hello { .. }) => WireResponse::Error {
                msg: "duplicate Hello: the handshake already completed".into(),
            },
            Ok(req) => dispatch(shared, client_id, req),
        };
        write_response(&mut stream, &resp)?;
    }
}

/// Map one wire request onto the serving plane. The mutex is held only
/// for the duration of the call — the pump clock advances exactly once
/// per `Pump` frame, whoever sends it.
fn dispatch(shared: &ConnShared, client_id: u64, req: WireRequest) -> WireResponse {
    // s2l-lint: allow(panic) reason=poisoned mutex means a peer thread crashed; propagating is policy
    let mut s = shared.server.lock().expect("node server mutex poisoned");
    match req {
        WireRequest::Hello { .. } => unreachable!("handled by serve_connection"),  // s2l-lint: allow(panic) reason=serve_connection consumes Hello before dispatch
        WireRequest::Predict { tenant, x, req_id } => deduped(shared, client_id, req_id, || {
            from_response(s.handle(tenant, Request::Predict(x)))
        }),
        WireRequest::Feedback {
            tenant,
            x,
            label,
            req_id,
        } => deduped(shared, client_id, req_id, || {
            from_response(s.handle(tenant, Request::Feedback(x, label as usize)))
        }),
        WireRequest::SwapAdapters { tenant, adapters } => {
            from_response(s.handle(tenant, Request::SwapAdapters(adapters)))
        }
        WireRequest::Observe => WireResponse::Observed {
            json: s.obs_snapshot().to_json().to_string(),
        },
        WireRequest::SaveState { path } => {
            from_response(s.handle(0, Request::SaveState(PathBuf::from(path))))
        }
        WireRequest::RestoreState { path } => {
            from_response(s.handle(0, Request::RestoreState(PathBuf::from(path))))
        }
        WireRequest::ExportTenant { tenant } => match s.export_tenant(tenant) {
            Ok(bytes) => WireResponse::TenantExported { bytes },
            Err(e) => WireResponse::Error { msg: e.to_string() },
        },
        WireRequest::ImportTenant { bytes } => match s.import_tenant(&bytes) {
            Ok((tenant, version)) => WireResponse::TenantImported { tenant, version },
            Err(e) => WireResponse::Error { msg: e.to_string() },
        },
        WireRequest::Drain => WireResponse::drained(&s.drain()),
        WireRequest::Pump => WireResponse::completions(&s.pump()),
        WireRequest::PumpDrain => WireResponse::completions(&s.pump_until_drained()),
        WireRequest::QueueDepth => WireResponse::QueueDepthOk {
            queued: s.queued() as u64,
        },
        WireRequest::Resume => {
            s.resume_admissions();
            WireResponse::Resumed
        }
    }
}

/// At-most-once wrapper for admissions: a `(client_id, req_id)` pair
/// already in the log replays its recorded response WITHOUT re-entering
/// the serving plane; a fresh pair executes and is recorded. Zero in
/// either field opts out (fire-once, the pre-v2 behavior).
fn deduped(
    shared: &ConnShared,
    client_id: u64,
    req_id: u64,
    run: impl FnOnce() -> WireResponse,
) -> WireResponse {
    if client_id == 0 || req_id == 0 {
        return run();
    }
    let key = (client_id, req_id);
    // s2l-lint: allow(panic) reason=poisoned mutex means a peer thread crashed; propagating is policy
    let mut log = shared.dedupe.lock().expect("dedupe log mutex poisoned");
    if let Some(prev) = log.get(key) {
        return prev;
    }
    let resp = run();
    log.put(key, resp.clone());
    resp
}

/// Serving-plane [`Response`] → wire frame. `Stats`/`Observed` carry
/// in-process-only payloads and are reached through their dedicated
/// wire frames instead, so they cannot appear here.
fn from_response(resp: Response) -> WireResponse {
    match resp {
        Response::Queued { ticket } => WireResponse::Queued { ticket },
        Response::Rejected(reason) => WireResponse::Rejected(reason),
        Response::Swapped { version } => WireResponse::Swapped { version },
        Response::Persisted(r) => WireResponse::persisted(&r),
        Response::Restored(r) => WireResponse::restored(&r),
        Response::Stats(_) | Response::Observed(_) => WireResponse::Error {
            msg: "internal: response has a dedicated wire frame".into(),
        },
    }
}
