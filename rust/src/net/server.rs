//! [`NodeServer`] — the TCP serving edge for one [`FleetServer`].
//!
//! A `NodeServer` owns the fleet server behind an `Arc<Mutex>`, binds a
//! listener (port 0 works: the kernel picks, [`NodeServer::addr`] tells),
//! and answers `skip2lora/wire/v1` frames from any number of concurrent
//! connections. Every connection must open with a valid `Hello`
//! handshake; anything else — wrong magic, wrong version, malformed
//! frame — gets a typed [`WireResponse::Error`], never a panic or a
//! silent close.
//!
//! Concurrency model: the accept loop and each connection run on plain
//! `std::thread`s, all checking one shared stop flag through short read
//! timeouts — no async runtime, no dependencies. Requests serialize
//! through the `Mutex`, which matches the serving plane's design: the
//! expensive work (backbone forwards, fine-tunes) already happens on the
//! batcher/worker-pool threads inside `FleetServer`; the lock only
//! covers enqueue/pump bookkeeping. Crucially the PUMP CLOCK stays with
//! whichever client drives `Pump`/`PumpDrain`, so a driver controls
//! batching determinism over the wire exactly as it would in-process.
//!
//! [`NodeServer::shutdown`] stops the accept loop, joins every
//! connection thread, and hands the inner [`FleetServer`] back — this is
//! how the multi-node tests "kill" a node and how a decommissioned
//! node's state can be inspected after its tenants have migrated away.

use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::serve::server::{FleetServer, Request, Response};
use crate::util::error::{anyhow, Context, Result};

use super::wire::{
    decode_request, write_response, WireRequest, WireResponse, MAX_FRAME_BYTES, WIRE_VERSION,
};

/// How long a blocked read waits before re-checking the stop flag.
const POLL: Duration = Duration::from_millis(25);

/// One fleet-server node listening on a TCP address.
pub struct NodeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    server: Arc<Mutex<FleetServer>>,
    accept_thread: JoinHandle<()>,
}

impl NodeServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `server` over the wire protocol.
    pub fn spawn(server: FleetServer, addr: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind node listener on {addr}"))?;
        let addr = listener
            .local_addr()
            .context("read bound listener address")?;
        listener
            .set_nonblocking(true)
            .context("set listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let server = Arc::new(Mutex::new(server));
        let accept_thread = {
            let stop = Arc::clone(&stop);
            let server = Arc::clone(&server);
            thread::spawn(move || accept_loop(listener, stop, server))
        };
        Ok(Self {
            addr,
            stop,
            server,
            accept_thread,
        })
    }

    /// The actual bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Run `f` against the inner server directly — for local drivers and
    /// tests that want in-process access (oracle comparisons) while the
    /// network edge is live. Serializes with wire requests via the same
    /// mutex, so it cannot observe a half-applied frame.
    pub fn with_server<R>(&self, f: impl FnOnce(&mut FleetServer) -> R) -> R {
        // s2l-lint: allow(panic) reason=poisoned mutex means a peer thread crashed; propagating is policy
        f(&mut self.server.lock().expect("node server mutex poisoned"))
    }

    /// Stop accepting, join every connection thread, and return the
    /// inner [`FleetServer`] (adapters, metrics and all). In-flight
    /// frames finish first — the stop flag is only checked between
    /// frames — so no response is ever torn mid-write.
    pub fn shutdown(self) -> FleetServer {
        let NodeServer {
            stop,
            server,
            accept_thread,
            ..
        } = self;
        stop.store(true, Ordering::SeqCst);
        let _ = accept_thread.join();
        // the accept loop joined every connection thread before exiting,
        // so ours is the last strong reference
        match Arc::try_unwrap(server) {
            Ok(m) => m.into_inner().expect("node server mutex poisoned"),  // s2l-lint: allow(panic) reason=poisoned mutex means a peer thread crashed; propagating is policy
            Err(_) => unreachable!("all connection threads were joined"),  // s2l-lint: allow(panic) reason=Arc::try_unwrap cannot fail after join()
        }
    }
}

fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>, server: Arc<Mutex<FleetServer>>) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(true);
                let _ = stream.set_read_timeout(Some(POLL));
                let stop = Arc::clone(&stop);
                let server = Arc::clone(&server);
                conns.push(thread::spawn(move || {
                    let _ = serve_connection(stream, stop, server);
                }));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => thread::sleep(Duration::from_millis(1)),
            // a failed accept (e.g. listener torn down) only ends the loop
            Err(_) => break,
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

/// Read one length-prefixed frame, waking every [`POLL`] to honor the
/// stop flag. `Ok(None)` means clean EOF before a frame started, or
/// stop. A connection dying MID-frame is an error, like a torn file.
fn read_frame_stoppable(stream: &mut TcpStream, stop: &AtomicBool) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        if stop.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match stream.read(&mut len_buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(anyhow!("connection closed mid length-prefix"));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
            Err(e) => return Err(anyhow!("read frame length: {e}")),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 {
        return Err(anyhow!("zero-length wire frame"));
    }
    if len > MAX_FRAME_BYTES {
        return Err(anyhow!(
            "announced frame of {len} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})"
        ));
    }
    let mut body = vec![0u8; len];
    let mut got = 0usize;
    while got < len {
        match stream.read(&mut body[got..]) {
            Ok(0) => return Err(anyhow!("connection closed mid frame body")),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
            Err(e) => return Err(anyhow!("read frame body: {e}")),
        }
    }
    Ok(Some(body))
}

fn serve_connection(
    mut stream: TcpStream,
    stop: Arc<AtomicBool>,
    server: Arc<Mutex<FleetServer>>,
) -> Result<()> {
    // handshake: the FIRST frame must be a well-formed Hello at our
    // version — anything else is answered with a typed Error and the
    // connection is closed
    let first = match read_frame_stoppable(&mut stream, &stop)? {
        Some(body) => body,
        None => return Ok(()),
    };
    match decode_request(&first) {
        Ok(WireRequest::Hello { version }) if version == WIRE_VERSION => {
            write_response(
                &mut stream,
                &WireResponse::HelloOk {
                    version: WIRE_VERSION,
                },
            )?;
        }
        Ok(WireRequest::Hello { version }) => {
            write_response(
                &mut stream,
                &WireResponse::Error {
                    msg: format!("wire version mismatch: client v{version}, server v{WIRE_VERSION}"),
                },
            )?;
            return Ok(());
        }
        Ok(other) => {
            write_response(
                &mut stream,
                &WireResponse::Error {
                    msg: format!("expected Hello as first frame, got {other:?}"),
                },
            )?;
            return Ok(());
        }
        Err(e) => {
            write_response(&mut stream, &WireResponse::Error { msg: e.to_string() })?;
            return Ok(());
        }
    }

    loop {
        let body = match read_frame_stoppable(&mut stream, &stop)? {
            Some(body) => body,
            None => return Ok(()),
        };
        let resp = match decode_request(&body) {
            // the framing survived, only this frame's content is bad —
            // answer with a typed error and keep the connection
            Err(e) => WireResponse::Error { msg: e.to_string() },
            Ok(WireRequest::Hello { .. }) => WireResponse::Error {
                msg: "duplicate Hello: the handshake already completed".into(),
            },
            Ok(req) => dispatch(&server, req),
        };
        write_response(&mut stream, &resp)?;
    }
}

/// Map one wire request onto the serving plane. The mutex is held only
/// for the duration of the call — the pump clock advances exactly once
/// per `Pump` frame, whoever sends it.
fn dispatch(server: &Mutex<FleetServer>, req: WireRequest) -> WireResponse {
    // s2l-lint: allow(panic) reason=poisoned mutex means a peer thread crashed; propagating is policy
    let mut s = server.lock().expect("node server mutex poisoned");
    match req {
        WireRequest::Hello { .. } => unreachable!("handled by serve_connection"),  // s2l-lint: allow(panic) reason=serve_connection consumes Hello before dispatch
        WireRequest::Predict { tenant, x } => from_response(s.handle(tenant, Request::Predict(x))),
        WireRequest::Feedback { tenant, x, label } => {
            from_response(s.handle(tenant, Request::Feedback(x, label as usize)))
        }
        WireRequest::SwapAdapters { tenant, adapters } => {
            from_response(s.handle(tenant, Request::SwapAdapters(adapters)))
        }
        WireRequest::Observe => WireResponse::Observed {
            json: s.obs_snapshot().to_json().to_string(),
        },
        WireRequest::SaveState { path } => {
            from_response(s.handle(0, Request::SaveState(PathBuf::from(path))))
        }
        WireRequest::RestoreState { path } => {
            from_response(s.handle(0, Request::RestoreState(PathBuf::from(path))))
        }
        WireRequest::ExportTenant { tenant } => match s.export_tenant(tenant) {
            Ok(bytes) => WireResponse::TenantExported { bytes },
            Err(e) => WireResponse::Error { msg: e.to_string() },
        },
        WireRequest::ImportTenant { bytes } => match s.import_tenant(&bytes) {
            Ok((tenant, version)) => WireResponse::TenantImported { tenant, version },
            Err(e) => WireResponse::Error { msg: e.to_string() },
        },
        WireRequest::Drain => WireResponse::drained(&s.drain()),
        WireRequest::Pump => WireResponse::completions(&s.pump()),
        WireRequest::PumpDrain => WireResponse::completions(&s.pump_until_drained()),
        WireRequest::QueueDepth => WireResponse::QueueDepthOk {
            queued: s.queued() as u64,
        },
        WireRequest::Resume => {
            s.resume_admissions();
            WireResponse::Resumed
        }
    }
}

/// Serving-plane [`Response`] → wire frame. `Stats`/`Observed` carry
/// in-process-only payloads and are reached through their dedicated
/// wire frames instead, so they cannot appear here.
fn from_response(resp: Response) -> WireResponse {
    match resp {
        Response::Queued { ticket } => WireResponse::Queued { ticket },
        Response::Rejected(reason) => WireResponse::Rejected(reason),
        Response::Swapped { version } => WireResponse::Swapped { version },
        Response::Persisted(r) => WireResponse::persisted(&r),
        Response::Restored(r) => WireResponse::restored(&r),
        Response::Stats(_) | Response::Observed(_) => WireResponse::Error {
            msg: "internal: response has a dedicated wire frame".into(),
        },
    }
}
