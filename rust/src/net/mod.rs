//! # net — the dependency-free TCP serving edge (DESIGN.md §12)
//!
//! Everything a `FleetServer` can do in-process, over a socket: the
//! `skip2lora/wire/v1` protocol ([`wire`]: versioned `Hello` handshake,
//! `u32`-length-prefixed frames, bounded sizes, typed decode errors with
//! the same trust-nothing discipline as the `.s2l` parser), a threaded
//! std-only server ([`server::NodeServer`]) and a blocking client
//! ([`client::NodeClient`]).
//!
//! Design rule: the protocol is strictly request→response and the PUMP
//! CLOCK crosses the wire as explicit `Pump`/`PumpDrain` frames. The
//! server never pushes, never batches on a timer, never owns time — so
//! a driver (the fleet router, a test, an example) gets the exact same
//! deterministic micro-batching semantics over TCP that it gets calling
//! `FleetServer::pump()` directly, and bit-identity is checkable across
//! the network boundary.
//!
//! The fleet layer ([`crate::fleet`]) builds on this: N `NodeServer`s +
//! rendezvous routing + drain-and-migrate tenant movement.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{
    Admission, ClientConfig, ClientError, ClientResult, NodeClient, TransportError,
};
pub use server::{NodeServer, NodeServerConfig};
pub use wire::{
    WireCompletion, WireRequest, WireResponse, MAX_FRAME_BYTES, WIRE_VERSION,
};
