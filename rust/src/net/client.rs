//! [`NodeClient`] — a blocking `skip2lora/wire/v1` client.
//!
//! One client drives one connection, strictly request→response:
//! [`NodeClient::connect`] performs the `Hello`/`HelloOk` handshake (a
//! version-mismatched or non-skip2lora peer fails HERE, with a typed
//! error), after which every method writes one frame and reads exactly
//! one frame back. There is no receive thread and no correlation state —
//! the protocol's strict alternation makes the client this simple, and
//! keeps the pump clock under the caller's control.
//!
//! Typed-surface convention: data-plane admissions return [`Admission`]
//! (queued vs typed [`RejectReason`] — both are normal outcomes a router
//! must branch on), while transport faults and server-side failures
//! (`WireResponse::Error`) surface as `Err`.

use std::net::TcpStream;

use crate::nn::lora::LoraAdapter;
use crate::serve::server::{Completion, DrainReport, RejectReason};
use crate::serve::TenantId;
use crate::util::error::{bail, Context, Result};

use super::wire::{
    read_response, write_request, WireRequest, WireResponse, WIRE_VERSION,
};

/// Outcome of a Predict/Feedback admission attempt — mirrors the
/// serving plane's `Queued`/`Rejected` split.
#[derive(Clone, Debug, PartialEq)]
pub enum Admission {
    Queued { ticket: u64 },
    Rejected(RejectReason),
}

/// A connected, handshaken wire client for one node.
pub struct NodeClient {
    stream: TcpStream,
}

impl NodeClient {
    /// Connect and handshake. Fails with a typed error if the peer is
    /// not a `skip2lora/wire/v1` server at exactly [`WIRE_VERSION`].
    pub fn connect(addr: &str) -> Result<Self> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect to node at {addr}"))?;
        stream.set_nodelay(true).context("set TCP_NODELAY")?;
        let mut client = Self { stream };
        match client.rpc(&WireRequest::Hello {
            version: WIRE_VERSION,
        })? {
            WireResponse::HelloOk { version } if version == WIRE_VERSION => Ok(client),
            WireResponse::HelloOk { version } => {
                bail!("server answered hello at wire version {version}, expected {WIRE_VERSION}")
            }
            WireResponse::Error { msg } => bail!("handshake rejected: {msg}"),
            other => bail!("unexpected handshake response {other:?}"),
        }
    }

    /// One raw request→response exchange. The building block every
    /// typed method below uses; public for tests and tooling that want
    /// to speak frames directly.
    pub fn rpc(&mut self, req: &WireRequest) -> Result<WireResponse> {
        write_request(&mut self.stream, req)?;
        read_response(&mut self.stream)
    }

    pub fn predict(&mut self, tenant: TenantId, x: Vec<f32>) -> Result<Admission> {
        match self.rpc(&WireRequest::Predict { tenant, x })? {
            WireResponse::Queued { ticket } => Ok(Admission::Queued { ticket }),
            WireResponse::Rejected(reason) => Ok(Admission::Rejected(reason)),
            other => bail!("unexpected response to Predict: {other:?}"),
        }
    }

    pub fn feedback(&mut self, tenant: TenantId, x: Vec<f32>, label: u32) -> Result<Admission> {
        match self.rpc(&WireRequest::Feedback { tenant, x, label })? {
            WireResponse::Queued { ticket } => Ok(Admission::Queued { ticket }),
            WireResponse::Rejected(reason) => Ok(Admission::Rejected(reason)),
            other => bail!("unexpected response to Feedback: {other:?}"),
        }
    }

    /// Install externally trained adapters; returns the new published
    /// version, or the typed rejection (shape/rank mismatch).
    pub fn swap_adapters(
        &mut self,
        tenant: TenantId,
        adapters: Vec<LoraAdapter>,
    ) -> Result<std::result::Result<u64, RejectReason>> {
        match self.rpc(&WireRequest::SwapAdapters { tenant, adapters })? {
            WireResponse::Swapped { version } => Ok(Ok(version)),
            WireResponse::Rejected(reason) => Ok(Err(reason)),
            other => bail!("unexpected response to SwapAdapters: {other:?}"),
        }
    }

    /// Advance the node's pump clock one tick; returns what completed.
    pub fn pump(&mut self) -> Result<Vec<Completion>> {
        match self.rpc(&WireRequest::Pump)? {
            WireResponse::Completions(cs) => {
                Ok(cs.into_iter().map(|c| c.into_completion()).collect())
            }
            other => bail!("unexpected response to Pump: {other:?}"),
        }
    }

    /// Pump until the node's queue is empty; returns every completion.
    pub fn pump_drain(&mut self) -> Result<Vec<Completion>> {
        match self.rpc(&WireRequest::PumpDrain)? {
            WireResponse::Completions(cs) => {
                Ok(cs.into_iter().map(|c| c.into_completion()).collect())
            }
            other => bail!("unexpected response to PumpDrain: {other:?}"),
        }
    }

    pub fn queue_depth(&mut self) -> Result<usize> {
        match self.rpc(&WireRequest::QueueDepth)? {
            WireResponse::QueueDepthOk { queued } => Ok(queued as usize),
            other => bail!("unexpected response to QueueDepth: {other:?}"),
        }
    }

    /// The node's `skip2lora/obs/v1` snapshot as JSON text — feed N of
    /// these into `obs::fleet::merge_texts` for the fleet view.
    pub fn observe(&mut self) -> Result<String> {
        match self.rpc(&WireRequest::Observe)? {
            WireResponse::Observed { json } => Ok(json),
            other => bail!("unexpected response to Observe: {other:?}"),
        }
    }

    /// Checkpoint the node's registry to a path ON THE NODE'S HOST;
    /// returns (tenants, bytes).
    pub fn save_state(&mut self, path: &str) -> Result<(u64, u64)> {
        match self.rpc(&WireRequest::SaveState { path: path.into() })? {
            WireResponse::Persisted { tenants, bytes } => Ok((tenants, bytes)),
            WireResponse::Rejected(reason) => bail!("SaveState rejected: {reason:?}"),
            other => bail!("unexpected response to SaveState: {other:?}"),
        }
    }

    /// Install a checkpoint from the node's host filesystem; returns
    /// (tenants, installed, max_version).
    pub fn restore_state(&mut self, path: &str) -> Result<(u64, u64, u64)> {
        match self.rpc(&WireRequest::RestoreState { path: path.into() })? {
            WireResponse::Restored {
                tenants,
                installed,
                max_version,
            } => Ok((tenants, installed, max_version)),
            WireResponse::Rejected(reason) => bail!("RestoreState rejected: {reason:?}"),
            other => bail!("unexpected response to RestoreState: {other:?}"),
        }
    }

    /// Pull one tenant's published adapters as a validated checkpoint
    /// payload — the source half of a migration.
    pub fn export_tenant(&mut self, tenant: TenantId) -> Result<Vec<u8>> {
        match self.rpc(&WireRequest::ExportTenant { tenant })? {
            WireResponse::TenantExported { bytes } => Ok(bytes),
            WireResponse::Error { msg } => bail!("ExportTenant failed: {msg}"),
            other => bail!("unexpected response to ExportTenant: {other:?}"),
        }
    }

    /// Install an exported tenant payload — the destination half of a
    /// migration. The destination allocates the version.
    pub fn import_tenant(&mut self, bytes: Vec<u8>) -> Result<(TenantId, u64)> {
        match self.rpc(&WireRequest::ImportTenant { bytes })? {
            WireResponse::TenantImported { tenant, version } => Ok((tenant, version)),
            WireResponse::Error { msg } => bail!("ImportTenant failed: {msg}"),
            other => bail!("unexpected response to ImportTenant: {other:?}"),
        }
    }

    /// Close admissions and flush the node (see `FleetServer::drain`);
    /// the report lets the caller balance the books.
    pub fn drain(&mut self) -> Result<DrainReport> {
        match self.rpc(&WireRequest::Drain)? {
            WireResponse::Drained {
                queued_at_start,
                finetunes_joined,
                completions,
            } => Ok(DrainReport {
                queued_at_start: queued_at_start as usize,
                finetunes_joined: finetunes_joined as usize,
                completions: completions.into_iter().map(|c| c.into_completion()).collect(),
            }),
            other => bail!("unexpected response to Drain: {other:?}"),
        }
    }

    /// Re-open admissions after a drain.
    pub fn resume(&mut self) -> Result<()> {
        match self.rpc(&WireRequest::Resume)? {
            WireResponse::Resumed => Ok(()),
            other => bail!("unexpected response to Resume: {other:?}"),
        }
    }
}
