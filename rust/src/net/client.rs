//! [`NodeClient`] — a blocking, fault-hardened `skip2lora/wire` client.
//!
//! One client drives one connection, strictly request→response:
//! [`NodeClient::connect`] performs the `Hello`/`HelloOk` handshake (a
//! version-mismatched or non-skip2lora peer fails HERE, with a typed
//! error), after which every method writes one frame and reads exactly
//! one frame back. There is no receive thread and no correlation state —
//! the protocol's strict alternation makes the client this simple, and
//! keeps the pump clock under the caller's control.
//!
//! Unattended-edge hardening (DESIGN.md §15): every socket operation is
//! bounded by [`ClientConfig`] timeouts (`TcpStream::connect_timeout`,
//! `set_read_timeout`, `set_write_timeout`), so a peer that dies mid-read
//! can stall a call for at most `rpc_timeout` — never hang it. Errors
//! split into a taxonomy callers can branch on:
//!
//! - [`ClientError::Transport`] — the socket failed (refused, reset, cut
//!   mid-frame, timed out). Carries `retryable`: the request may not have
//!   been executed, so a retry (after [`NodeClient::reconnect`]) is
//!   reasonable — with the SAME `req_id` when the outcome was ambiguous,
//!   so the server's admission-dedupe log keeps it at-most-once.
//! - [`ClientError::Protocol`] — the peer violated `skip2lora/wire`
//!   (garbage frame, wrong version, unauthorized). Retrying cannot help.
//! - [`ClientError::Server`] — the server executed the request and
//!   reported failure (`WireResponse::Error`). Not a transport fault.
//!
//! A transport fault poisons the connection (a half-read frame cannot be
//! resynchronized); further calls fail fast with a retryable error until
//! [`NodeClient::reconnect`] re-dials and re-handshakes.
//!
//! Typed-surface convention: data-plane admissions return [`Admission`]
//! (queued vs typed [`RejectReason`] — both are normal outcomes a router
//! must branch on), while faults surface as `Err(ClientError)`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::nn::lora::LoraAdapter;
use crate::serve::server::{Completion, DrainReport, RejectReason};
use crate::serve::TenantId;

use super::wire::{
    decode_response, encode_request, WireRequest, WireResponse, MAX_FRAME_BYTES, WIRE_VERSION,
};

/// Outcome of a Predict/Feedback admission attempt — mirrors the
/// serving plane's `Queued`/`Rejected` split.
#[derive(Clone, Debug, PartialEq)]
pub enum Admission {
    Queued { ticket: u64 },
    Rejected(RejectReason),
}

/// A socket-layer fault. `retryable` means the request may simply not
/// have reached (or not have answered from) the peer — reconnecting and
/// retrying is reasonable; `false` means the fault is structural (bad
/// address, refused credentials at the socket layer) and retrying the
/// same way cannot help.
#[derive(Clone, Debug, PartialEq)]
pub struct TransportError {
    pub retryable: bool,
    pub msg: String,
}

/// The client-side error taxonomy (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum ClientError {
    Transport(TransportError),
    Protocol(String),
    Server(String),
}

impl ClientError {
    fn transport(retryable: bool, msg: impl Into<String>) -> Self {
        ClientError::Transport(TransportError {
            retryable,
            msg: msg.into(),
        })
    }

    fn io(ctx: &str, e: &std::io::Error) -> Self {
        // every io fault on an established flow is worth one retry: the
        // taxonomy distinguishes "socket broke" from "peer is insane",
        // not transient from permanent — the health machine does that
        Self::transport(true, format!("{ctx}: {e}"))
    }

    /// Should a caller reconnect-and-retry this request?
    pub fn is_retryable(&self) -> bool {
        matches!(self, ClientError::Transport(t) if t.retryable)
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Transport(t) => write!(
                f,
                "transport error ({}): {}",
                if t.retryable { "retryable" } else { "fatal" },
                t.msg
            ),
            ClientError::Protocol(msg) => write!(f, "protocol violation: {msg}"),
            ClientError::Server(msg) => write!(f, "server error: {msg}"),
        }
    }
}

impl std::error::Error for ClientError {}

/// Per-call result alias for the client surface.
pub type ClientResult<T> = std::result::Result<T, ClientError>;

/// Socket-level hardening knobs plus the handshake credentials.
///
/// `backoff_ticks` is deliberately a PUMP-TICK count, not a duration:
/// the fleet health machine (`fleet/health.rs`) schedules probe retries
/// of suspect nodes on the deterministic pump clock, so recovery replays
/// bit-identically in tests — wall-clock backoff would not.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientConfig {
    /// bound on `TcpStream::connect` (refused/black-holed dials)
    pub connect_timeout: Duration,
    /// bound on every request→response exchange (read + write timeouts)
    pub rpc_timeout: Duration,
    /// per-RPC retry budget a router may spend on retryable faults
    /// against the SAME node before failing over
    pub max_retries: u32,
    /// pump ticks a suspect node waits before its next probe
    pub backoff_ticks: u64,
    /// shared-secret presented in the `Hello`; must match the server's
    pub token: Option<String>,
    /// logical client identity for admission dedupe; 0 opts out
    pub client_id: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(5),
            rpc_timeout: Duration::from_secs(5),
            max_retries: 2,
            backoff_ticks: 4,
            token: None,
            client_id: 0,
        }
    }
}

/// A connected, handshaken wire client for one node.
pub struct NodeClient {
    stream: TcpStream,
    addr: String,
    cfg: ClientConfig,
    /// set on any transport fault: a half-exchanged connection cannot be
    /// resynchronized, so calls fail fast until `reconnect`
    broken: bool,
}

impl NodeClient {
    /// Connect and handshake with default [`ClientConfig`]. Fails with a
    /// typed error if the peer is not a `skip2lora/wire` server at
    /// exactly [`WIRE_VERSION`].
    pub fn connect(addr: &str) -> ClientResult<Self> {
        Self::connect_with(addr, ClientConfig::default())
    }

    /// Connect and handshake with explicit timeouts and credentials.
    pub fn connect_with(addr: &str, cfg: ClientConfig) -> ClientResult<Self> {
        let stream = dial(addr, &cfg)?;
        let mut client = Self {
            stream,
            addr: addr.to_string(),
            cfg,
            broken: false,
        };
        client.handshake()?;
        Ok(client)
    }

    /// Drop the (possibly poisoned) connection, re-dial, re-handshake.
    /// The config — including `client_id`, which keys the server's
    /// admission-dedupe log — carries over, so a retry after reconnect
    /// can safely reuse an ambiguous request's `req_id`.
    pub fn reconnect(&mut self) -> ClientResult<()> {
        self.stream = dial(&self.addr, &self.cfg)?;
        self.broken = false;
        self.handshake()
    }

    /// Has a transport fault poisoned this connection?
    pub fn is_broken(&self) -> bool {
        self.broken
    }

    pub fn config(&self) -> &ClientConfig {
        &self.cfg
    }

    fn handshake(&mut self) -> ClientResult<()> {
        let hello = WireRequest::Hello {
            version: WIRE_VERSION,
            token: self.cfg.token.clone(),
            client_id: self.cfg.client_id,
        };
        match self.rpc(&hello)? {
            WireResponse::HelloOk { version } if version == WIRE_VERSION => Ok(()),
            WireResponse::HelloOk { version } => Err(ClientError::Protocol(format!(
                "server answered hello at wire version {version}, expected {WIRE_VERSION}"
            ))),
            WireResponse::Unauthorized => Err(ClientError::Server(
                "handshake unauthorized: wrong or missing auth token".into(),
            )),
            WireResponse::Busy { limit } => Err(ClientError::transport(
                true,
                format!("server at connection cap ({limit})"),
            )),
            WireResponse::Error { msg } => {
                Err(ClientError::Server(format!("handshake rejected: {msg}")))
            }
            other => Err(ClientError::Protocol(format!(
                "unexpected handshake response {other:?}"
            ))),
        }
    }

    /// One raw request→response exchange, bounded by `rpc_timeout` on
    /// both directions. The building block every typed method below
    /// uses; public for tests and tooling that want to speak frames
    /// directly.
    pub fn rpc(&mut self, req: &WireRequest) -> ClientResult<WireResponse> {
        if self.broken {
            return Err(ClientError::transport(
                true,
                "connection poisoned by an earlier transport fault; reconnect first",
            ));
        }
        let body = encode_request(req);
        if let Err(e) = self.write_frame_raw(&body) {
            self.broken = true;
            return Err(e);
        }
        let resp_body = match self.read_frame_raw() {
            Ok(b) => b,
            Err(e) => {
                self.broken = true;
                return Err(e);
            }
        };
        // decode failures are NOT transport faults: the socket delivered
        // a complete frame, its contents were nonsense
        decode_response(&resp_body).map_err(|e| ClientError::Protocol(e.to_string()))
    }

    fn write_frame_raw(&mut self, body: &[u8]) -> ClientResult<()> {
        if body.is_empty() || body.len() > MAX_FRAME_BYTES {
            return Err(ClientError::Protocol(format!(
                "refusing to write a {}-byte frame (max {MAX_FRAME_BYTES})",
                body.len()
            )));
        }
        let len = u32::try_from(body.len())
            .map_err(|_| ClientError::Protocol("frame length does not fit in u32".into()))?;
        self.stream
            .write_all(&len.to_le_bytes())
            .map_err(|e| ClientError::io("write frame length", &e))?;
        self.stream
            .write_all(body)
            .map_err(|e| ClientError::io("write frame body", &e))?;
        self.stream
            .flush()
            .map_err(|e| ClientError::io("flush frame", &e))
    }

    fn read_frame_raw(&mut self) -> ClientResult<Vec<u8>> {
        let mut len_buf = [0u8; 4];
        self.stream
            .read_exact(&mut len_buf)
            .map_err(|e| ClientError::io("read frame length", &e))?;
        let len = usize::try_from(u32::from_le_bytes(len_buf))
            .map_err(|_| ClientError::Protocol("frame length does not fit in usize".into()))?;
        if len == 0 || len > MAX_FRAME_BYTES {
            return Err(ClientError::Protocol(format!(
                "announced frame of {len} bytes outside (0, {MAX_FRAME_BYTES}]"
            )));
        }
        let mut body = vec![0u8; len];
        self.stream
            .read_exact(&mut body)
            .map_err(|e| ClientError::io("read frame body", &e))?;
        Ok(body)
    }

    fn admission(resp: WireResponse, what: &str) -> ClientResult<Admission> {
        match resp {
            WireResponse::Queued { ticket } => Ok(Admission::Queued { ticket }),
            WireResponse::Rejected(reason) => Ok(Admission::Rejected(reason)),
            other => Err(ClientError::Protocol(format!(
                "unexpected response to {what}: {other:?}"
            ))),
        }
    }

    pub fn predict(&mut self, tenant: TenantId, x: Vec<f32>) -> ClientResult<Admission> {
        self.predict_req(tenant, x, 0)
    }

    /// `Predict` with an explicit `req_id` (the at-most-once handle). A
    /// retry of an ambiguous outcome MUST pass the same `req_id`.
    pub fn predict_req(
        &mut self,
        tenant: TenantId,
        x: Vec<f32>,
        req_id: u64,
    ) -> ClientResult<Admission> {
        let resp = self.rpc(&WireRequest::Predict { tenant, x, req_id })?;
        Self::admission(resp, "Predict")
    }

    pub fn feedback(
        &mut self,
        tenant: TenantId,
        x: Vec<f32>,
        label: u32,
    ) -> ClientResult<Admission> {
        self.feedback_req(tenant, x, label, 0)
    }

    /// `Feedback` with an explicit `req_id` (the at-most-once handle).
    pub fn feedback_req(
        &mut self,
        tenant: TenantId,
        x: Vec<f32>,
        label: u32,
        req_id: u64,
    ) -> ClientResult<Admission> {
        let resp = self.rpc(&WireRequest::Feedback {
            tenant,
            x,
            label,
            req_id,
        })?;
        Self::admission(resp, "Feedback")
    }

    /// Install externally trained adapters; returns the new published
    /// version, or the typed rejection (shape/rank mismatch).
    pub fn swap_adapters(
        &mut self,
        tenant: TenantId,
        adapters: Vec<LoraAdapter>,
    ) -> ClientResult<std::result::Result<u64, RejectReason>> {
        match self.rpc(&WireRequest::SwapAdapters { tenant, adapters })? {
            WireResponse::Swapped { version } => Ok(Ok(version)),
            WireResponse::Rejected(reason) => Ok(Err(reason)),
            other => Err(ClientError::Protocol(format!(
                "unexpected response to SwapAdapters: {other:?}"
            ))),
        }
    }

    /// Advance the node's pump clock one tick; returns what completed.
    pub fn pump(&mut self) -> ClientResult<Vec<Completion>> {
        match self.rpc(&WireRequest::Pump)? {
            WireResponse::Completions(cs) => {
                Ok(cs.into_iter().map(|c| c.into_completion()).collect())
            }
            other => Err(ClientError::Protocol(format!(
                "unexpected response to Pump: {other:?}"
            ))),
        }
    }

    /// Pump until the node's queue is empty; returns every completion.
    pub fn pump_drain(&mut self) -> ClientResult<Vec<Completion>> {
        match self.rpc(&WireRequest::PumpDrain)? {
            WireResponse::Completions(cs) => {
                Ok(cs.into_iter().map(|c| c.into_completion()).collect())
            }
            other => Err(ClientError::Protocol(format!(
                "unexpected response to PumpDrain: {other:?}"
            ))),
        }
    }

    pub fn queue_depth(&mut self) -> ClientResult<usize> {
        match self.rpc(&WireRequest::QueueDepth)? {
            WireResponse::QueueDepthOk { queued } => usize::try_from(queued).map_err(|_| {
                ClientError::Protocol(format!("queue depth {queued} does not fit in usize"))
            }),
            other => Err(ClientError::Protocol(format!(
                "unexpected response to QueueDepth: {other:?}"
            ))),
        }
    }

    /// The node's `skip2lora/obs/v1` snapshot as JSON text — feed N of
    /// these into `obs::fleet::merge_texts` for the fleet view.
    pub fn observe(&mut self) -> ClientResult<String> {
        match self.rpc(&WireRequest::Observe)? {
            WireResponse::Observed { json } => Ok(json),
            other => Err(ClientError::Protocol(format!(
                "unexpected response to Observe: {other:?}"
            ))),
        }
    }

    /// Checkpoint the node's registry to a path ON THE NODE'S HOST;
    /// returns (tenants, bytes).
    pub fn save_state(&mut self, path: &str) -> ClientResult<(u64, u64)> {
        match self.rpc(&WireRequest::SaveState { path: path.into() })? {
            WireResponse::Persisted { tenants, bytes } => Ok((tenants, bytes)),
            WireResponse::Rejected(reason) => {
                Err(ClientError::Server(format!("SaveState rejected: {reason:?}")))
            }
            other => Err(ClientError::Protocol(format!(
                "unexpected response to SaveState: {other:?}"
            ))),
        }
    }

    /// Install a checkpoint from the node's host filesystem; returns
    /// (tenants, installed, max_version).
    pub fn restore_state(&mut self, path: &str) -> ClientResult<(u64, u64, u64)> {
        match self.rpc(&WireRequest::RestoreState { path: path.into() })? {
            WireResponse::Restored {
                tenants,
                installed,
                max_version,
            } => Ok((tenants, installed, max_version)),
            WireResponse::Rejected(reason) => Err(ClientError::Server(format!(
                "RestoreState rejected: {reason:?}"
            ))),
            other => Err(ClientError::Protocol(format!(
                "unexpected response to RestoreState: {other:?}"
            ))),
        }
    }

    /// Pull one tenant's published adapters as a validated checkpoint
    /// payload — the source half of a migration.
    pub fn export_tenant(&mut self, tenant: TenantId) -> ClientResult<Vec<u8>> {
        match self.rpc(&WireRequest::ExportTenant { tenant })? {
            WireResponse::TenantExported { bytes } => Ok(bytes),
            WireResponse::Error { msg } => {
                Err(ClientError::Server(format!("ExportTenant failed: {msg}")))
            }
            other => Err(ClientError::Protocol(format!(
                "unexpected response to ExportTenant: {other:?}"
            ))),
        }
    }

    /// Install an exported tenant payload — the destination half of a
    /// migration. The destination allocates the version.
    pub fn import_tenant(&mut self, bytes: Vec<u8>) -> ClientResult<(TenantId, u64)> {
        match self.rpc(&WireRequest::ImportTenant { bytes })? {
            WireResponse::TenantImported { tenant, version } => Ok((tenant, version)),
            WireResponse::Error { msg } => {
                Err(ClientError::Server(format!("ImportTenant failed: {msg}")))
            }
            other => Err(ClientError::Protocol(format!(
                "unexpected response to ImportTenant: {other:?}"
            ))),
        }
    }

    /// Close admissions and flush the node (see `FleetServer::drain`);
    /// the report lets the caller balance the books.
    pub fn drain(&mut self) -> ClientResult<DrainReport> {
        match self.rpc(&WireRequest::Drain)? {
            WireResponse::Drained {
                queued_at_start,
                finetunes_joined,
                completions,
            } => Ok(DrainReport {
                queued_at_start: queued_at_start as usize,  // s2l-lint: allow(cast) reason=u64 to usize widening on our targets
                finetunes_joined: finetunes_joined as usize,  // s2l-lint: allow(cast) reason=u64 to usize widening on our targets
                completions: completions.into_iter().map(|c| c.into_completion()).collect(),
            }),
            other => Err(ClientError::Protocol(format!(
                "unexpected response to Drain: {other:?}"
            ))),
        }
    }

    /// Re-open admissions after a drain.
    pub fn resume(&mut self) -> ClientResult<()> {
        match self.rpc(&WireRequest::Resume)? {
            WireResponse::Resumed => Ok(()),
            other => Err(ClientError::Protocol(format!(
                "unexpected response to Resume: {other:?}"
            ))),
        }
    }
}

/// Resolve, dial with `connect_timeout`, and arm the per-exchange
/// read/write timeouts — after this, no call on the stream can block
/// longer than `rpc_timeout`.
fn dial(addr: &str, cfg: &ClientConfig) -> ClientResult<TcpStream> {
    // an unresolvable address is structural, not transient
    let sock: SocketAddr = addr
        .to_socket_addrs()
        .map_err(|e| ClientError::transport(false, format!("resolve {addr}: {e}")))?
        .next()
        .ok_or_else(|| ClientError::transport(false, format!("{addr} resolves to no address")))?;
    let stream = if cfg.connect_timeout.is_zero() {
        TcpStream::connect(sock)
    } else {
        TcpStream::connect_timeout(&sock, cfg.connect_timeout)
    }
    .map_err(|e| ClientError::io(&format!("connect to node at {addr}"), &e))?;
    stream
        .set_nodelay(true)
        .map_err(|e| ClientError::io("set TCP_NODELAY", &e))?;
    let rpc_timeout = if cfg.rpc_timeout.is_zero() {
        None
    } else {
        Some(cfg.rpc_timeout)
    };
    stream
        .set_read_timeout(rpc_timeout)
        .map_err(|e| ClientError::io("set read timeout", &e))?;
    stream
        .set_write_timeout(rpc_timeout)
        .map_err(|e| ClientError::io("set write timeout", &e))?;
    Ok(stream)
}
