//! The `skip2lora/wire/v1` frame codec — a dependency-free, versioned,
//! length-prefixed binary protocol for driving a [`FleetServer`] over a
//! byte stream (DESIGN.md §12).
//!
//! Layout (little-endian) of one frame on the wire:
//!
//! ```text
//!   u32 len | u8 tag | payload (len - 1 bytes)
//! ```
//!
//! `len` covers the tag byte plus the payload and is bounded by
//! [`MAX_FRAME_BYTES`]; a peer announcing a larger frame is rejected
//! BEFORE any allocation happens. Connections open with a
//! [`WireRequest::Hello`] carrying the `S2LW` magic and
//! [`WIRE_VERSION`], answered by [`WireResponse::HelloOk`] — a client
//! speaking a different protocol (or a different version of this one) is
//! turned away with a typed error at the handshake, not garbage later.
//!
//! The protocol is strictly request→response: the server NEVER pushes an
//! unsolicited frame. Predict/Feedback only enqueue (answered by
//! `Queued`); completions are pulled with explicit `Pump` / `PumpDrain`
//! frames, which keeps the deterministic pump clock in the *driver's*
//! hands — the property every bit-identity test in this repo leans on.
//!
//! Decoding trusts nothing: same discipline as `model/io.rs`
//! (`TensorBundle::from_bytes`). Every read is bounds-checked through one
//! cursor, all size math is `checked_*`, trailing bytes after a complete
//! frame are an error, unknown tags are an error with the tag value, and
//! nothing in this module can panic on adversarial input
//! (`tests/net_wire.rs` sweeps every truncation point of every frame).

use std::io::{Read, Write};

use crate::nn::lora::LoraAdapter;
use crate::serve::server::{Completion, DrainReport, PersistReport, RejectReason, RestoreReport};
use crate::serve::TenantId;
use crate::tensor::Mat;
use crate::util::error::{bail, Context, Result};

/// First bytes of every `Hello` payload — identifies the protocol itself.
pub const MAGIC: &[u8; 4] = b"S2LW";

/// Protocol version carried in the `Hello`/`HelloOk` handshake. Bump on
/// any incompatible frame change; a server rejects mismatched clients
/// with a typed [`WireResponse::Error`].
///
/// v2: `Hello` gained an optional auth token + a `client_id`, and
/// `Predict`/`Feedback` gained a `req_id` for at-most-once admission
/// (DESIGN.md §15) — all fixed-position fields, hence the bump.
pub const WIRE_VERSION: u16 = 2;

/// Hard cap on `len` (tag + payload). Generous enough for a full-fleet
/// `ImportTenant` checkpoint or an `Observed` snapshot, small enough
/// that a hostile length prefix cannot drive a multi-GiB allocation.
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

// request tags (0x01..=0x7F)
const T_HELLO: u8 = 0x01;
const T_PREDICT: u8 = 0x02;
const T_FEEDBACK: u8 = 0x03;
const T_SWAP: u8 = 0x04;
const T_OBSERVE: u8 = 0x05;
const T_SAVE: u8 = 0x06;
const T_RESTORE: u8 = 0x07;
const T_EXPORT: u8 = 0x08;
const T_IMPORT: u8 = 0x09;
const T_DRAIN: u8 = 0x0A;
const T_PUMP: u8 = 0x0B;
const T_PUMP_DRAIN: u8 = 0x0C;
const T_QUEUE_DEPTH: u8 = 0x0D;
const T_RESUME: u8 = 0x0E;

// response tags (0x81..=0xFF)
const T_HELLO_OK: u8 = 0x81;
const T_QUEUED: u8 = 0x82;
const T_REJECTED: u8 = 0x83;
const T_SWAPPED: u8 = 0x84;
const T_OBSERVED: u8 = 0x85;
const T_PERSISTED: u8 = 0x86;
const T_RESTORED: u8 = 0x87;
const T_EXPORTED: u8 = 0x88;
const T_IMPORTED: u8 = 0x89;
const T_DRAINED: u8 = 0x8A;
const T_COMPLETIONS: u8 = 0x8B;
const T_QUEUE_DEPTH_OK: u8 = 0x8C;
const T_RESUMED: u8 = 0x8D;
const T_UNAUTHORIZED: u8 = 0x8E;
const T_BUSY: u8 = 0x8F;
const T_ERROR: u8 = 0xFF;

// reject-reason codes inside a `Rejected` payload
const R_QUEUE_FULL: u8 = 1;
const R_RATE_LIMITED: u8 = 2;
const R_MALFORMED: u8 = 3;
const R_PERSIST_FAILED: u8 = 4;
const R_DRAINING: u8 = 5;

/// A client→server frame. One-to-one with the subset of
/// [`crate::serve::Request`] that makes sense over a wire, plus the
/// handshake, migration, drain, and explicit pump-clock frames.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    /// Protocol handshake: magic + version; MUST be the first frame.
    /// `token` is the optional shared-secret credential (checked by the
    /// server before any other verb). `client_id` names the logical
    /// client for at-most-once admission dedupe — 0 opts out.
    Hello {
        version: u16,
        token: Option<String>,
        client_id: u64,
    },
    /// `req_id` keys the server-side admission-dedupe log together with
    /// the connection's `client_id`; 0 means "no dedupe" (fire-once).
    /// A retry of an *ambiguous* admission MUST reuse the same `req_id`.
    Predict {
        tenant: TenantId,
        x: Vec<f32>,
        req_id: u64,
    },
    Feedback {
        tenant: TenantId,
        x: Vec<f32>,
        label: u32,
        req_id: u64,
    },
    SwapAdapters { tenant: TenantId, adapters: Vec<LoraAdapter> },
    /// pull the node's `skip2lora/obs/v1` snapshot (returned as JSON text)
    Observe,
    SaveState { path: String },
    RestoreState { path: String },
    /// serialize one tenant's published adapters for migration
    ExportTenant { tenant: TenantId },
    /// install a tenant checkpoint produced by `ExportTenant` elsewhere
    ImportTenant { bytes: Vec<u8> },
    /// close admissions, flush the queue, join fine-tunes
    Drain,
    /// advance the deterministic pump clock by one tick
    Pump,
    /// pump until the queue is empty
    PumpDrain,
    /// how many requests are waiting (lets a driver pace its pumps)
    QueueDepth,
    /// re-open admissions after a `Drain`
    Resume,
}

/// A served request as it crosses the wire — field-for-field the serving
/// plane's [`Completion`], with explicit option encoding.
#[derive(Clone, Debug, PartialEq)]
pub struct WireCompletion {
    pub tenant: TenantId,
    pub ticket: u64,
    pub prediction: u32,
    pub label: Option<u32>,
    pub correct: Option<bool>,
    pub adapter_version: u64,
}

impl From<&Completion> for WireCompletion {
    fn from(c: &Completion) -> Self {
        Self {
            tenant: c.tenant,
            ticket: c.ticket,
            prediction: c.prediction as u32,  // s2l-lint: allow(cast) reason=class index, bounded by n_classes
            label: c.label.map(|l| l as u32),  // s2l-lint: allow(cast) reason=class index, bounded by n_classes
            correct: c.correct,
            adapter_version: c.adapter_version,
        }
    }
}

impl WireCompletion {
    /// Back to the serving plane's type (the router hands these to code
    /// that cannot tell local from remote completions).
    pub fn into_completion(self) -> Completion {
        Completion {
            tenant: self.tenant,
            ticket: self.ticket,
            prediction: self.prediction as usize,  // s2l-lint: allow(cast) reason=u32 to usize widening on our targets
            label: self.label.map(|l| l as usize),  // s2l-lint: allow(cast) reason=u32 to usize widening on our targets
            correct: self.correct,
            adapter_version: self.adapter_version,
        }
    }
}

/// A server→client frame.
#[derive(Clone, Debug, PartialEq)]
pub enum WireResponse {
    HelloOk { version: u16 },
    Queued { ticket: u64 },
    /// typed end-to-end: the client gets back the same [`RejectReason`]
    /// the serving plane produced, so a router can react per-variant
    /// (re-route on `Draining`, back off on `QueueFull`, …)
    Rejected(RejectReason),
    Swapped { version: u64 },
    /// the node's `skip2lora/obs/v1` snapshot as JSON text
    Observed { json: String },
    Persisted { tenants: u64, bytes: u64 },
    Restored { tenants: u64, installed: u64, max_version: u64 },
    TenantExported { bytes: Vec<u8> },
    TenantImported { tenant: TenantId, version: u64 },
    Drained {
        queued_at_start: u64,
        finetunes_joined: u64,
        completions: Vec<WireCompletion>,
    },
    Completions(Vec<WireCompletion>),
    QueueDepthOk { queued: u64 },
    Resumed,
    /// handshake carried a wrong or missing auth token — the connection
    /// is closed after this frame, before any other verb is served
    Unauthorized,
    /// server is at its connection cap; retry later or elsewhere
    Busy { limit: u64 },
    /// any server-side failure that is not a typed rejection
    Error { msg: String },
}

impl WireResponse {
    pub fn persisted(r: &PersistReport) -> Self {
        WireResponse::Persisted {
            tenants: r.tenants as u64,
            bytes: r.bytes as u64,
        }
    }

    pub fn restored(r: &RestoreReport) -> Self {
        WireResponse::Restored {
            tenants: r.tenants as u64,
            installed: r.installed as u64,
            max_version: r.max_version,
        }
    }

    pub fn drained(r: &DrainReport) -> Self {
        WireResponse::Drained {
            queued_at_start: r.queued_at_start as u64,
            finetunes_joined: r.finetunes_joined as u64,
            completions: r.completions.iter().map(WireCompletion::from).collect(),
        }
    }

    pub fn completions(cs: &[Completion]) -> Self {
        WireResponse::Completions(cs.iter().map(WireCompletion::from).collect())
    }
}

// ---------------------------------------------------------------------------
// encoding

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    put_u32(buf, b.len() as u32);  // s2l-lint: allow(cast) reason=encode side; in-process size, frame bounded by MAX_FRAME_BYTES
    buf.extend_from_slice(b);
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_bytes(buf, s.as_bytes());
}

fn put_floats(buf: &mut Vec<u8>, xs: &[f32]) {
    put_u32(buf, xs.len() as u32);  // s2l-lint: allow(cast) reason=encode side; in-process size, frame bounded by MAX_FRAME_BYTES
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_adapters(buf: &mut Vec<u8>, adapters: &[LoraAdapter]) {
    put_u32(buf, adapters.len() as u32);  // s2l-lint: allow(cast) reason=encode side; in-process size, frame bounded by MAX_FRAME_BYTES
    for a in adapters {
        // (n_in, rank, n_out) then wa row-major, wb row-major — the
        // dims pin both shapes, so the float counts are implied
        put_u32(buf, a.wa.rows as u32);  // s2l-lint: allow(cast) reason=encode side; in-process size, frame bounded by MAX_FRAME_BYTES
        put_u32(buf, a.wa.cols as u32);  // s2l-lint: allow(cast) reason=encode side; in-process size, frame bounded by MAX_FRAME_BYTES
        put_u32(buf, a.wb.cols as u32);  // s2l-lint: allow(cast) reason=encode side; in-process size, frame bounded by MAX_FRAME_BYTES
        for v in &a.wa.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for v in &a.wb.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

fn put_completion(buf: &mut Vec<u8>, c: &WireCompletion) {
    put_u64(buf, c.tenant);
    put_u64(buf, c.ticket);
    put_u32(buf, c.prediction);
    match c.label {
        None => buf.push(0),
        Some(l) => {
            buf.push(1);
            put_u32(buf, l);
        }
    }
    // 0 = absent, 1 = Some(false), 2 = Some(true)
    buf.push(match c.correct {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    });
    put_u64(buf, c.adapter_version);
}

fn put_completions(buf: &mut Vec<u8>, cs: &[WireCompletion]) {
    put_u32(buf, cs.len() as u32);  // s2l-lint: allow(cast) reason=encode side; in-process size, frame bounded by MAX_FRAME_BYTES
    for c in cs {
        put_completion(buf, c);
    }
}

/// Encode a request as `tag + payload` (no length prefix — that is the
/// stream layer's job, see [`write_frame`]).
pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    let mut buf = Vec::new();
    match req {
        WireRequest::Hello {
            version,
            token,
            client_id,
        } => {
            buf.push(T_HELLO);
            buf.extend_from_slice(MAGIC);
            put_u16(&mut buf, *version);
            match token {
                None => buf.push(0),
                Some(t) => {
                    buf.push(1);
                    put_str(&mut buf, t);
                }
            }
            put_u64(&mut buf, *client_id);
        }
        WireRequest::Predict { tenant, x, req_id } => {
            buf.push(T_PREDICT);
            put_u64(&mut buf, *tenant);
            put_floats(&mut buf, x);
            put_u64(&mut buf, *req_id);
        }
        WireRequest::Feedback {
            tenant,
            x,
            label,
            req_id,
        } => {
            buf.push(T_FEEDBACK);
            put_u64(&mut buf, *tenant);
            put_floats(&mut buf, x);
            put_u32(&mut buf, *label);
            put_u64(&mut buf, *req_id);
        }
        WireRequest::SwapAdapters { tenant, adapters } => {
            buf.push(T_SWAP);
            put_u64(&mut buf, *tenant);
            put_adapters(&mut buf, adapters);
        }
        WireRequest::Observe => buf.push(T_OBSERVE),
        WireRequest::SaveState { path } => {
            buf.push(T_SAVE);
            put_str(&mut buf, path);
        }
        WireRequest::RestoreState { path } => {
            buf.push(T_RESTORE);
            put_str(&mut buf, path);
        }
        WireRequest::ExportTenant { tenant } => {
            buf.push(T_EXPORT);
            put_u64(&mut buf, *tenant);
        }
        WireRequest::ImportTenant { bytes } => {
            buf.push(T_IMPORT);
            put_bytes(&mut buf, bytes);
        }
        WireRequest::Drain => buf.push(T_DRAIN),
        WireRequest::Pump => buf.push(T_PUMP),
        WireRequest::PumpDrain => buf.push(T_PUMP_DRAIN),
        WireRequest::QueueDepth => buf.push(T_QUEUE_DEPTH),
        WireRequest::Resume => buf.push(T_RESUME),
    }
    buf
}

/// Encode a response as `tag + payload`.
pub fn encode_response(resp: &WireResponse) -> Vec<u8> {
    let mut buf = Vec::new();
    match resp {
        WireResponse::HelloOk { version } => {
            buf.push(T_HELLO_OK);
            put_u16(&mut buf, *version);
        }
        WireResponse::Queued { ticket } => {
            buf.push(T_QUEUED);
            put_u64(&mut buf, *ticket);
        }
        WireResponse::Rejected(reason) => {
            buf.push(T_REJECTED);
            match reason {
                RejectReason::QueueFull { bound } => {
                    buf.push(R_QUEUE_FULL);
                    put_u64(&mut buf, *bound as u64);
                }
                RejectReason::RateLimited => buf.push(R_RATE_LIMITED),
                RejectReason::Malformed(msg) => {
                    buf.push(R_MALFORMED);
                    put_str(&mut buf, msg);
                }
                RejectReason::PersistFailed(msg) => {
                    buf.push(R_PERSIST_FAILED);
                    put_str(&mut buf, msg);
                }
                RejectReason::Draining => buf.push(R_DRAINING),
            }
        }
        WireResponse::Swapped { version } => {
            buf.push(T_SWAPPED);
            put_u64(&mut buf, *version);
        }
        WireResponse::Observed { json } => {
            buf.push(T_OBSERVED);
            put_str(&mut buf, json);
        }
        WireResponse::Persisted { tenants, bytes } => {
            buf.push(T_PERSISTED);
            put_u64(&mut buf, *tenants);
            put_u64(&mut buf, *bytes);
        }
        WireResponse::Restored {
            tenants,
            installed,
            max_version,
        } => {
            buf.push(T_RESTORED);
            put_u64(&mut buf, *tenants);
            put_u64(&mut buf, *installed);
            put_u64(&mut buf, *max_version);
        }
        WireResponse::TenantExported { bytes } => {
            buf.push(T_EXPORTED);
            put_bytes(&mut buf, bytes);
        }
        WireResponse::TenantImported { tenant, version } => {
            buf.push(T_IMPORTED);
            put_u64(&mut buf, *tenant);
            put_u64(&mut buf, *version);
        }
        WireResponse::Drained {
            queued_at_start,
            finetunes_joined,
            completions,
        } => {
            buf.push(T_DRAINED);
            put_u64(&mut buf, *queued_at_start);
            put_u64(&mut buf, *finetunes_joined);
            put_completions(&mut buf, completions);
        }
        WireResponse::Completions(cs) => {
            buf.push(T_COMPLETIONS);
            put_completions(&mut buf, cs);
        }
        WireResponse::QueueDepthOk { queued } => {
            buf.push(T_QUEUE_DEPTH_OK);
            put_u64(&mut buf, *queued);
        }
        WireResponse::Resumed => buf.push(T_RESUMED),
        WireResponse::Unauthorized => buf.push(T_UNAUTHORIZED),
        WireResponse::Busy { limit } => {
            buf.push(T_BUSY);
            put_u64(&mut buf, *limit);
        }
        WireResponse::Error { msg } => {
            buf.push(T_ERROR);
            put_str(&mut buf, msg);
        }
    }
    buf
}

// ---------------------------------------------------------------------------
// decoding

/// Bounds-checked cursor over one frame body — the `model/io.rs` `take`
/// discipline (`n > len - p`, which cannot overflow because `p <= len`)
/// packaged for a protocol with many frame shapes. Every decode error is
/// a typed `Error`; nothing here panics on adversarial bytes.
struct Rd<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, p: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.b.len() - self.p {
            bail!(
                "truncated wire frame: need {n} bytes at offset {}, have {}",
                self.p,
                self.b.len() - self.p
            );
        }
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))  // s2l-lint: allow(index) reason=fixed offsets into a take(N)-guarded slice
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))  // s2l-lint: allow(index) reason=fixed offsets into a take(N)-guarded slice
    }

    /// A u32 length/count field decoded to usize via `try_from`, never
    /// `as`: on a 16-bit usize target a hostile length would otherwise
    /// wrap into a small in-bounds value and desynchronize the frame.
    fn len(&mut self) -> Result<usize> {
        let v = self.u32()?;
        usize::try_from(v).with_context(|| format!("length {v} does not fit in usize"))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],  // s2l-lint: allow(index) reason=take(8) guarantees length
        ]))
    }

    /// u32 length + raw bytes; the length is validated against the
    /// remaining frame BEFORE any allocation.
    fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.len()?;
        self.take(n)
    }

    fn string(&mut self) -> Result<String> {
        let raw = self.bytes()?;
        String::from_utf8(raw.to_vec()).context("non-UTF-8 string in wire frame")
    }

    /// u32 count + count f32s. The byte size is computed CHECKED and
    /// validated against the remaining frame before the vector is built,
    /// so a hostile count can neither wrap the math nor drive an
    /// oversized allocation.
    fn floats(&mut self) -> Result<Vec<f32>> {
        let n = self.len()?;
        let nbytes = n
            .checked_mul(4)
            .with_context(|| format!("float count {n} overflows byte math"))?;
        let raw = self.take(nbytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))  // s2l-lint: allow(index) reason=chunks_exact(4) guarantees length
            .collect())
    }

    fn exact_floats(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let nbytes = n
            .checked_mul(4)
            .with_context(|| format!("{what}: float count {n} overflows byte math"))?;
        let raw = self.take(nbytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))  // s2l-lint: allow(index) reason=chunks_exact(4) guarantees length
            .collect())
    }

    fn adapters(&mut self) -> Result<Vec<LoraAdapter>> {
        let count = self.len()?;
        let mut out = Vec::new();
        for i in 0..count {
            let n_in = self.len()?;
            let rank = self.len()?;
            let n_out = self.len()?;
            let wa_len = n_in
                .checked_mul(rank)
                .with_context(|| format!("adapter {i}: wa dims {n_in}x{rank} overflow"))?;
            let wb_len = rank
                .checked_mul(n_out)
                .with_context(|| format!("adapter {i}: wb dims {rank}x{n_out} overflow"))?;
            let wa = self.exact_floats(wa_len, "adapter wa")?;
            let wb = self.exact_floats(wb_len, "adapter wb")?;
            out.push(LoraAdapter {
                wa: Mat::from_vec(n_in, rank, wa),
                wb: Mat::from_vec(rank, n_out, wb),
            });
        }
        Ok(out)
    }

    fn completion(&mut self) -> Result<WireCompletion> {
        let tenant = self.u64()?;
        let ticket = self.u64()?;
        let prediction = self.u32()?;
        let label = match self.u8()? {
            0 => None,
            1 => Some(self.u32()?),
            other => bail!("bad label presence byte {other} in completion"),
        };
        let correct = match self.u8()? {
            0 => None,
            1 => Some(false),
            2 => Some(true),
            other => bail!("bad correctness byte {other} in completion"),
        };
        let adapter_version = self.u64()?;
        Ok(WireCompletion {
            tenant,
            ticket,
            prediction,
            label,
            correct,
            adapter_version,
        })
    }

    fn completions(&mut self) -> Result<Vec<WireCompletion>> {
        let n = self.len()?;
        let mut out = Vec::new();
        for _ in 0..n {
            out.push(self.completion()?);
        }
        Ok(out)
    }

    /// A complete frame must consume every byte — trailing garbage means
    /// a confused (or malicious) peer, and is rejected like truncation.
    fn finish(&self) -> Result<()> {
        if self.p != self.b.len() {
            bail!(
                "trailing bytes in wire frame: {} consumed, {} present",
                self.p,
                self.b.len()
            );
        }
        Ok(())
    }
}

/// Decode one request frame body (`tag + payload`, no length prefix).
pub fn decode_request(body: &[u8]) -> Result<WireRequest> {
    let mut rd = Rd::new(body);
    let tag = rd.u8().context("empty wire frame")?;
    let req = match tag {
        T_HELLO => {
            let magic = rd.take(4)?;
            if magic != MAGIC {
                bail!("bad hello magic {magic:?}: not a skip2lora/wire peer");
            }
            let version = rd.u16()?;
            let token = match rd.u8()? {
                0 => None,
                1 => Some(rd.string()?),
                other => bail!("bad hello token presence byte {other}"),
            };
            WireRequest::Hello {
                version,
                token,
                client_id: rd.u64()?,
            }
        }
        T_PREDICT => WireRequest::Predict {
            tenant: rd.u64()?,
            x: rd.floats()?,
            req_id: rd.u64()?,
        },
        T_FEEDBACK => WireRequest::Feedback {
            tenant: rd.u64()?,
            x: rd.floats()?,
            label: rd.u32()?,
            req_id: rd.u64()?,
        },
        T_SWAP => WireRequest::SwapAdapters {
            tenant: rd.u64()?,
            adapters: rd.adapters()?,
        },
        T_OBSERVE => WireRequest::Observe,
        T_SAVE => WireRequest::SaveState { path: rd.string()? },
        T_RESTORE => WireRequest::RestoreState { path: rd.string()? },
        T_EXPORT => WireRequest::ExportTenant { tenant: rd.u64()? },
        T_IMPORT => WireRequest::ImportTenant {
            bytes: rd.bytes()?.to_vec(),
        },
        T_DRAIN => WireRequest::Drain,
        T_PUMP => WireRequest::Pump,
        T_PUMP_DRAIN => WireRequest::PumpDrain,
        T_QUEUE_DEPTH => WireRequest::QueueDepth,
        T_RESUME => WireRequest::Resume,
        other => bail!("unknown request frame tag 0x{other:02X}"),
    };
    rd.finish()?;
    Ok(req)
}

/// Decode one response frame body (`tag + payload`, no length prefix).
pub fn decode_response(body: &[u8]) -> Result<WireResponse> {
    let mut rd = Rd::new(body);
    let tag = rd.u8().context("empty wire frame")?;
    let resp = match tag {
        T_HELLO_OK => WireResponse::HelloOk { version: rd.u16()? },
        T_QUEUED => WireResponse::Queued { ticket: rd.u64()? },
        T_REJECTED => {
            let code = rd.u8()?;
            let reason = match code {
                R_QUEUE_FULL => RejectReason::QueueFull {
                    bound: {
                        let b = rd.u64()?;
                        usize::try_from(b)
                            .with_context(|| format!("queue bound {b} does not fit in usize"))?
                    },
                },
                R_RATE_LIMITED => RejectReason::RateLimited,
                R_MALFORMED => RejectReason::Malformed(rd.string()?),
                R_PERSIST_FAILED => RejectReason::PersistFailed(rd.string()?),
                R_DRAINING => RejectReason::Draining,
                other => bail!("unknown reject-reason code {other}"),
            };
            WireResponse::Rejected(reason)
        }
        T_SWAPPED => WireResponse::Swapped { version: rd.u64()? },
        T_OBSERVED => WireResponse::Observed { json: rd.string()? },
        T_PERSISTED => WireResponse::Persisted {
            tenants: rd.u64()?,
            bytes: rd.u64()?,
        },
        T_RESTORED => WireResponse::Restored {
            tenants: rd.u64()?,
            installed: rd.u64()?,
            max_version: rd.u64()?,
        },
        T_EXPORTED => WireResponse::TenantExported {
            bytes: rd.bytes()?.to_vec(),
        },
        T_IMPORTED => WireResponse::TenantImported {
            tenant: rd.u64()?,
            version: rd.u64()?,
        },
        T_DRAINED => WireResponse::Drained {
            queued_at_start: rd.u64()?,
            finetunes_joined: rd.u64()?,
            completions: rd.completions()?,
        },
        T_COMPLETIONS => WireResponse::Completions(rd.completions()?),
        T_QUEUE_DEPTH_OK => WireResponse::QueueDepthOk { queued: rd.u64()? },
        T_RESUMED => WireResponse::Resumed,
        T_UNAUTHORIZED => WireResponse::Unauthorized,
        T_BUSY => WireResponse::Busy { limit: rd.u64()? },
        T_ERROR => WireResponse::Error { msg: rd.string()? },
        other => bail!("unknown response frame tag 0x{other:02X}"),
    };
    rd.finish()?;
    Ok(resp)
}

// ---------------------------------------------------------------------------
// stream layer

/// Write one length-prefixed frame. `body` is `tag + payload` as
/// produced by [`encode_request`] / [`encode_response`].
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> Result<()> {
    if body.is_empty() {
        bail!("refusing to write an empty wire frame");
    }
    if body.len() > MAX_FRAME_BYTES {
        bail!(
            "frame of {} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})",
            body.len()
        );
    }
    let len = u32::try_from(body.len()).context("frame length does not fit in u32")?;
    w.write_all(&len.to_le_bytes()).context("write frame length")?;
    w.write_all(body).context("write frame body")?;
    w.flush().context("flush frame")?;
    Ok(())
}

/// Read one length-prefixed frame body. The announced length is bounds-
/// checked (non-zero, ≤ [`MAX_FRAME_BYTES`]) BEFORE the body allocation,
/// so a hostile prefix cannot drive an oversized allocation; a stream
/// that ends mid-frame is a typed error.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf).context("read frame length")?;
    let len = usize::try_from(u32::from_le_bytes(len_buf)).context("frame length does not fit in usize")?;
    if len == 0 {
        bail!("zero-length wire frame");
    }
    if len > MAX_FRAME_BYTES {
        bail!("announced frame of {len} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)
        .with_context(|| format!("read {len}-byte frame body"))?;
    Ok(body)
}

/// [`encode_request`] + [`write_frame`].
pub fn write_request(w: &mut impl Write, req: &WireRequest) -> Result<()> {
    write_frame(w, &encode_request(req))
}

/// [`read_frame`] + [`decode_request`].
pub fn read_request(r: &mut impl Read) -> Result<WireRequest> {
    decode_request(&read_frame(r)?)
}

/// [`encode_response`] + [`write_frame`].
pub fn write_response(w: &mut impl Write, resp: &WireResponse) -> Result<()> {
    write_frame(w, &encode_response(resp))
}

/// [`read_frame`] + [`decode_response`].
pub fn read_response(r: &mut impl Read) -> Result<WireResponse> {
    decode_response(&read_frame(r)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_completion() -> WireCompletion {
        WireCompletion {
            tenant: 42,
            ticket: 7,
            prediction: 2,
            label: Some(1),
            correct: Some(false),
            adapter_version: 9,
        }
    }

    fn all_requests() -> Vec<WireRequest> {
        let adapter = LoraAdapter {
            wa: Mat::from_vec(3, 2, vec![0.5, -1.0, 2.0, 0.25, -0.125, 8.0]),
            wb: Mat::from_vec(2, 4, vec![1.0; 8]),
        };
        vec![
            WireRequest::Hello {
                version: WIRE_VERSION,
                token: None,
                client_id: 0,
            },
            WireRequest::Hello {
                version: WIRE_VERSION,
                token: Some("shared-secret".into()),
                client_id: 77,
            },
            WireRequest::Predict {
                tenant: 3,
                x: vec![0.1, -0.5, 1e9],
                req_id: 0,
            },
            WireRequest::Feedback {
                tenant: u64::MAX,
                x: vec![],
                label: 2,
                req_id: u64::MAX,
            },
            WireRequest::SwapAdapters {
                tenant: 17,
                adapters: vec![adapter.clone(), adapter],
            },
            WireRequest::Observe,
            WireRequest::SaveState {
                path: "/tmp/ck.s2l".into(),
            },
            WireRequest::RestoreState {
                path: "relative/ck.s2l".into(),
            },
            WireRequest::ExportTenant { tenant: 99 },
            WireRequest::ImportTenant {
                bytes: vec![1, 2, 3, 255, 0],
            },
            WireRequest::Drain,
            WireRequest::Pump,
            WireRequest::PumpDrain,
            WireRequest::QueueDepth,
            WireRequest::Resume,
        ]
    }

    fn all_responses() -> Vec<WireResponse> {
        vec![
            WireResponse::HelloOk {
                version: WIRE_VERSION,
            },
            WireResponse::Queued { ticket: 1234 },
            WireResponse::Rejected(RejectReason::QueueFull { bound: 1024 }),
            WireResponse::Rejected(RejectReason::RateLimited),
            WireResponse::Rejected(RejectReason::Malformed("dim 7 != 8".into())),
            WireResponse::Rejected(RejectReason::PersistFailed("torn file".into())),
            WireResponse::Rejected(RejectReason::Draining),
            WireResponse::Swapped { version: 5 },
            WireResponse::Observed {
                json: "{\"schema\":\"skip2lora/obs/v1\"}".into(),
            },
            WireResponse::Persisted {
                tenants: 3,
                bytes: 4096,
            },
            WireResponse::Restored {
                tenants: 3,
                installed: 2,
                max_version: 11,
            },
            WireResponse::TenantExported {
                bytes: vec![83, 50, 76, 49],
            },
            WireResponse::TenantImported {
                tenant: 42,
                version: 6,
            },
            WireResponse::Drained {
                queued_at_start: 2,
                finetunes_joined: 1,
                completions: vec![sample_completion()],
            },
            WireResponse::Completions(vec![
                sample_completion(),
                WireCompletion {
                    label: None,
                    correct: None,
                    ..sample_completion()
                },
                WireCompletion {
                    correct: Some(true),
                    ..sample_completion()
                },
            ]),
            WireResponse::QueueDepthOk { queued: 77 },
            WireResponse::Resumed,
            WireResponse::Unauthorized,
            WireResponse::Busy { limit: 64 },
            WireResponse::Error {
                msg: "tenant 5 has no published adapters".into(),
            },
        ]
    }

    #[test]
    fn every_request_roundtrips() {
        for req in all_requests() {
            let body = encode_request(&req);
            let back = decode_request(&body).unwrap_or_else(|e| panic!("{req:?}: {e}"));
            assert_eq!(back, req);
        }
    }

    #[test]
    fn every_response_roundtrips() {
        for resp in all_responses() {
            let body = encode_response(&resp);
            let back = decode_response(&body).unwrap_or_else(|e| panic!("{resp:?}: {e}"));
            assert_eq!(back, resp);
        }
    }

    #[test]
    fn roundtrip_through_the_stream_layer() {
        let mut stream = Vec::new();
        for req in all_requests() {
            write_request(&mut stream, &req).unwrap();
        }
        for resp in all_responses() {
            write_response(&mut stream, &resp).unwrap();
        }
        let mut r = stream.as_slice();
        for req in all_requests() {
            assert_eq!(read_request(&mut r).unwrap(), req);
        }
        for resp in all_responses() {
            assert_eq!(read_response(&mut r).unwrap(), resp);
        }
        assert!(r.is_empty());
    }

    #[test]
    fn adapter_floats_are_bit_exact() {
        let wa = vec![f32::MIN_POSITIVE, -0.0, 1.0e-38, 3.5];
        let req = WireRequest::SwapAdapters {
            tenant: 1,
            adapters: vec![LoraAdapter {
                wa: Mat::from_vec(2, 2, wa.clone()),
                wb: Mat::from_vec(2, 1, vec![f32::MAX, f32::MIN]),
            }],
        };
        match decode_request(&encode_request(&req)).unwrap() {
            WireRequest::SwapAdapters { adapters, .. } => {
                for (a, b) in adapters[0].wa.data.iter().zip(wa.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        for req in all_requests() {
            let mut body = encode_request(&req);
            body.push(0);
            assert!(
                decode_request(&body).is_err(),
                "{req:?} accepted a trailing byte"
            );
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let mut stream = Vec::new();
        stream.extend_from_slice(&(u32::MAX).to_le_bytes());
        stream.extend_from_slice(&[0u8; 16]);
        let err = read_frame(&mut stream.as_slice()).unwrap_err();
        assert!(err.to_string().contains("MAX_FRAME_BYTES"), "{err}");
    }

    #[test]
    fn zero_length_frame_is_rejected() {
        let stream = 0u32.to_le_bytes();
        assert!(read_frame(&mut stream.as_slice()).is_err());
    }

    #[test]
    fn hostile_float_count_cannot_wrap_byte_math() {
        // Predict frame claiming u32::MAX floats with a 4-byte body: the
        // checked_mul/take pair must reject it, not wrap or allocate
        let mut body = vec![T_PREDICT];
        put_u64(&mut body, 1);
        put_u32(&mut body, u32::MAX);
        body.extend_from_slice(&[0u8; 4]);
        assert!(decode_request(&body).is_err());
    }
}
