//! Stress tests for the hardened serve path: the sharded registry under
//! concurrent publish/read churn, and the `FleetServer` admission pipeline
//! (token bucket → bounded queue → batcher) plus TTL eviction under a
//! long seeded op mix.
//!
//! Everything is driven by `testkit::stress` (seeded workers + invariant
//! observers) or a seeded single-threaded op mix, so failures replay from
//! the printed seed. The `#[ignore]`-tagged tests are the long-running
//! versions: they stay out of the fast tier-1 loop and run in CI's
//! `stress` job via `cargo test --release -- --ignored`.

use skip2lora::model::MlpConfig;
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::serve::registry::AdapterRegistry;
use skip2lora::serve::{
    FleetServer, RateLimit, RejectReason, Request, Response, ServeConfig,
};
use skip2lora::tensor::ops::Backend;
use skip2lora::testkit::stress::{self, StressConfig};
use skip2lora::train::trainer::pretrain;
use skip2lora::util::rng::Rng;

// ---------------------------------------------------------------------
// sharded registry under concurrent publishers
// ---------------------------------------------------------------------

/// N publishers all hammering the SAME small tenant set (tenants spread
/// across shards): observers must never see a tenant's installed version
/// decrease, and the final installed version per tenant must be the
/// maximum version any publisher was allocated for it (a stale publisher
/// can never clobber a newer snapshot).
fn registry_monotonicity(shards: usize, workers: usize, ops: usize, seed: u64) {
    const TENANTS: usize = 6;
    let registry = AdapterRegistry::with_shards(shards);
    let cfg = StressConfig { workers, ops, observers: 2, seed };

    let report = stress::run(
        &cfg,
        &registry,
        // each worker publishes `ops` adapter sets to random tenants and
        // remembers the highest version it was allocated per tenant
        |mut ctx, reg: &AdapterRegistry| {
            let mut max_allocated = vec![0u64; TENANTS];
            for _ in 0..ctx.ops {
                let t = ctx.rng.below(TENANTS);
                let ads = (0..3)
                    .map(|_| LoraAdapter::new(&mut ctx.rng, 6, 2, 3))
                    .collect();
                let v = reg.publish(t as u64, ads);
                max_allocated[t] = max_allocated[t].max(v);
            }
            max_allocated
        },
        // observers: installed versions are monotone per tenant
        |ctx, reg: &AdapterRegistry| {
            let mut last = vec![0u64; TENANTS];
            let mut checks = 0u64;
            while ctx.workers_live() {
                for t in 0..TENANTS {
                    if let Some(snap) = reg.snapshot(t as u64) {
                        assert!(
                            snap.version >= last[t],
                            "tenant {t}: version {} < previously observed {} (seed {seed:#x})",
                            snap.version,
                            last[t]
                        );
                        last[t] = snap.version;
                    }
                }
                checks += 1;
            }
            checks
        },
    );

    for t in 0..TENANTS {
        let max_published = report.workers.iter().map(|w| w[t]).max().unwrap();
        assert_eq!(
            registry.version(t as u64),
            max_published,
            "tenant {t}: a stale publish clobbered the newest version (seed {seed:#x})"
        );
    }
    assert!(report.observers.iter().all(|&c| c > 0), "observers never ran");
    assert_eq!(
        registry.publishes(),
        (workers * ops) as u64,
        "every publish must be counted"
    );
}

#[test]
fn registry_versions_monotone_under_concurrent_publishers_across_shards() {
    registry_monotonicity(8, 4, 150, 0x5EED_0001);
    // the single-lock degenerate case obeys the same contract
    registry_monotonicity(1, 4, 150, 0x5EED_0002);
}

/// Long-running version: more shards, workers, and rounds. CI `stress`
/// job only (`cargo test --release -- --ignored`).
#[test]
#[ignore = "long-running stress; CI stress job runs it with --ignored"]
fn stress_registry_monotonicity_long() {
    for seed in 0..4u64 {
        registry_monotonicity(32, 16, 2000, 0xC0DE_0000 + seed);
    }
}

// ---------------------------------------------------------------------
// FleetServer admission pipeline under seeded churn
// ---------------------------------------------------------------------

fn stress_backbone() -> skip2lora::model::Mlp {
    let mut rng = Rng::new(0);
    let cfg = MlpConfig { dims: vec![8, 12, 12, 3], rank: 2, batch_norm: true };
    let n = 120;
    let mut x = skip2lora::tensor::Mat::zeros(n, 8);
    let mut labels = Vec::new();
    for i in 0..n {
        let c = i % 3;
        for j in 0..8 {
            let base = if j % 3 == c { 2.0 } else { 0.0 };
            *x.at_mut(i, j) = base + 0.3 * rng.normal();
        }
        labels.push(c);
    }
    let data = skip2lora::data::Dataset { x, labels, n_classes: 3 };
    pretrain(cfg, &data, 50, 0.05, 1, Backend::Blocked)
}

/// The admission pipeline under a phased, seeded load shape — each
/// hardening feature is driven into its rejection/eviction regime by
/// construction (not by hoping a random walk gets there), and the
/// tentpole invariants hold throughout:
///
/// * the queue NEVER exceeds its bound, and admitted + rejected
///   bookkeeping exactly matches `ServerStats`;
/// * every admitted request is eventually served (completions == admits);
/// * per-tenant registry versions only ever grow;
/// * idle tenants are evicted, yet no published version is ever dropped.
fn server_churn(steps: usize, n_tenants: u64, workers: usize, seed: u64) {
    const QUEUE_BOUND: usize = 24;
    const BURST: f64 = 6.0;
    let mut server = FleetServer::new(
        stress_backbone(),
        ServeConfig {
            batch_capacity: 8,
            queue_bound: QUEUE_BOUND,
            rate_limit: Some(RateLimit { burst: BURST, tokens_per_pump: 2.0 }),
            idle_ttl_pumps: Some(64),
            registry_shards: 8,
            window: 12,
            accuracy_threshold: 0.6,
            buffer_target: 16,
            epochs: 4,
            train_batch: 8,
            workers,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(seed);
    let sample = |rng: &mut Rng| -> Vec<f32> { (0..8).map(|_| rng.normal()).collect() };

    let mut admitted = 0u64;
    let mut queue_full = 0u64;
    let mut rate_limited = 0u64;
    let mut served = 0u64;
    let mut swap_version = vec![0u64; n_tenants as usize];
    let mut last_version = vec![0u64; n_tenants as usize];

    // Phase A — overload burst, zero pumps: with n_tenants × burst
    // admissible tokens exceeding the bound, the queue MUST fill and
    // reject the overflow. The bound is never exceeded.
    assert!(n_tenants as f64 * BURST > QUEUE_BOUND as f64 + 8.0, "phase A needs overload");
    for i in 0..(QUEUE_BOUND + 16) {
        let t = (i as u64) % n_tenants; // round-robin keeps buckets charged
        match server.handle(t, Request::Predict(sample(&mut rng))) {
            Response::Queued { .. } => admitted += 1,
            Response::Rejected(RejectReason::QueueFull { bound }) => {
                assert_eq!(bound, QUEUE_BOUND);
                queue_full += 1;
            }
            Response::Rejected(RejectReason::RateLimited) => rate_limited += 1,
            other => panic!("phase A: {other:?} (seed {seed:#x})"),
        }
        assert!(server.queued() <= QUEUE_BOUND, "queue exceeded its bound");
    }
    assert!(queue_full >= 16, "overload burst never hit the queue bound");
    served += server.pump_until_drained().len() as u64;

    // Phase B — one hot tenant past its bucket: more requests in one
    // tick than the bucket can hold ⇒ rate-limiting MUST trigger.
    let before_rate_limited = rate_limited;
    for _ in 0..(BURST as usize + 6) {
        match server.handle(0, Request::Predict(sample(&mut rng))) {
            Response::Queued { .. } => admitted += 1,
            Response::Rejected(RejectReason::RateLimited) => rate_limited += 1,
            other => panic!("phase B: {other:?} (seed {seed:#x})"),
        }
    }
    assert!(rate_limited > before_rate_limited, "hot tenant never rate-limited");
    served += server.pump_until_drained().len() as u64;

    // Phase C — seeded mixed churn (Predict / Feedback / SwapAdapters /
    // pumps) with the invariants checked at every step.
    for step in 0..steps {
        let t = rng.below(n_tenants as usize) as u64;
        match rng.below(10) {
            0..=4 => {
                let label = rng.below(3);
                match server.handle(t, Request::Feedback(sample(&mut rng), label)) {
                    Response::Queued { .. } => admitted += 1,
                    Response::Rejected(RejectReason::QueueFull { bound }) => {
                        assert_eq!(bound, QUEUE_BOUND);
                        queue_full += 1;
                    }
                    Response::Rejected(RejectReason::RateLimited) => rate_limited += 1,
                    other => panic!("step {step}: {other:?} (seed {seed:#x})"),
                }
            }
            5..=7 => match server.handle(t, Request::Predict(sample(&mut rng))) {
                Response::Queued { .. } => admitted += 1,
                Response::Rejected(RejectReason::QueueFull { .. }) => queue_full += 1,
                Response::Rejected(RejectReason::RateLimited) => rate_limited += 1,
                other => panic!("step {step}: {other:?} (seed {seed:#x})"),
            },
            8 => {
                let ads: Vec<LoraAdapter> = [8usize, 12, 12]
                    .iter()
                    .map(|&n_in| LoraAdapter::new(&mut rng, n_in, 2, 3))
                    .collect();
                match server.handle(t, Request::SwapAdapters(ads)) {
                    Response::Swapped { version } => {
                        let ti = t as usize;
                        assert!(version > swap_version[ti], "versions must grow");
                        swap_version[ti] = version;
                    }
                    other => panic!("step {step}: {other:?} (seed {seed:#x})"),
                }
            }
            _ => served += server.pump().len() as u64,
        }
        // THE back-pressure invariant: bounded, always
        assert!(
            server.queued() <= QUEUE_BOUND,
            "step {step}: queue {} exceeded its bound (seed {seed:#x})",
            server.queued()
        );
        // registry versions are monotone per tenant under serving churn
        let ti = t as usize;
        let v = server.tenant_version(t);
        assert!(
            v >= last_version[ti],
            "step {step}: tenant {t} version went backwards (seed {seed:#x})"
        );
        last_version[ti] = v;
    }
    served += server.pump_until_drained().len() as u64;
    server.quiesce();
    served += server.pump_until_drained().len() as u64;

    // Phase D — cooldown: the whole fleet goes idle for > TTL pumps, so
    // every tenant's serve state MUST be evicted (no job is in flight
    // after quiesce)...
    for _ in 0..160 {
        served += server.pump().len() as u64;
    }
    let stats = server.stats();
    assert_eq!(server.tenant_count(), 0, "idle tenants survived the TTL sweep");
    assert!(stats.evictions > 0, "TTL sweep never evicted: {stats:?}");

    // ...and the books balance exactly.
    assert_eq!(stats.queue_rejections, queue_full, "queue rejections miscounted");
    assert_eq!(stats.rate_limited, rate_limited, "rate-limit rejections miscounted");
    assert_eq!(served, admitted, "an admitted request was never served");
    assert_eq!(stats.queued, 0);
    assert_eq!(stats.registry_shards, 8);
    // eviction never drops published adapters: every swapped version is
    // still installed (or superseded by a later fine-tune publish)
    for t in 0..n_tenants {
        assert!(
            server.tenant_version(t) >= swap_version[t as usize],
            "tenant {t}: eviction dropped a published version (seed {seed:#x})"
        );
    }
    server.shutdown();
}

#[test]
fn server_admission_pipeline_survives_seeded_churn() {
    server_churn(3000, 12, 0, 0xFEED_0001);
}

/// Long-running version with a background worker pool. CI `stress` job
/// only (`cargo test --release -- --ignored`).
#[test]
#[ignore = "long-running stress; CI stress job runs it with --ignored"]
fn stress_server_churn_long() {
    server_churn(40_000, 48, 2, 0xFEED_1001);
    server_churn(40_000, 48, 2, 0xFEED_1002);
}
