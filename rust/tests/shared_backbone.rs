//! The split-state acceptance tests: one `Arc<Mlp>` backbone driven
//! concurrently from N threads (serving micro-batcher + fine-tune
//! workers) must produce BIT-IDENTICAL logits and adapter trajectories
//! to the old cloned-backbone discipline — and the sharing itself must be
//! provable (compile-time `Send + Sync`, runtime pointer identity).

use std::sync::Arc;

use skip2lora::data::Dataset;
use skip2lora::method::Method;
use skip2lora::model::mlp::AdapterTopology;
use skip2lora::model::{AdapterSet, ExecCtx, Mlp, MlpConfig};
use skip2lora::nn::batchnorm::BatchNorm;
use skip2lora::nn::fc::FcLayer;
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::serve::batcher::{BatchRequest, FrozenBackbone, MicroBatcher};
use skip2lora::serve::registry::AdapterRegistry;
use skip2lora::tensor::{ops::Backend, Mat};
use skip2lora::testkit::stress::{self, StressConfig};
use skip2lora::testkit::{assert_send, assert_send_sync};
use skip2lora::train::FineTuner;
use skip2lora::util::rng::Rng;
use skip2lora::util::timer::PhaseTimer;

/// Compile-time: the backbone (and each parameter-only layer type) is
/// `Send + Sync`; the per-thread context is `Send`. Monomorphizing the
/// testkit helpers IS the assertion — a `RefCell` regression in any layer
/// makes this test fail to compile.
#[test]
fn backbone_types_are_send_sync() {
    assert_send_sync::<Mlp>();
    assert_send_sync::<FcLayer>();
    assert_send_sync::<BatchNorm>();
    assert_send_sync::<LoraAdapter>();
    assert_send_sync::<AdapterSet>();
    assert_send::<ExecCtx>();
}

fn cfg() -> MlpConfig {
    MlpConfig { dims: vec![10, 12, 12, 3], rank: 2, batch_norm: true }
}

fn clustered(seed: u64, n: usize) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Mat::zeros(n, 10);
    let mut labels = Vec::new();
    for i in 0..n {
        let c = i % 3;
        for j in 0..10 {
            let base = if j % 3 == c { 2.0 } else { 0.0 };
            *x.at_mut(i, j) = base + 0.3 * rng.normal();
        }
        labels.push(c);
    }
    Dataset { x, labels, n_classes: 3 }
}

/// Run one Skip2-LoRA fine-tune to completion; returns the trained
/// adapters and the per-step losses.
fn finetune(
    model: impl Into<Arc<Mlp>>,
    adapters: AdapterSet,
    data: &Dataset,
    steps: usize,
) -> (AdapterSet, Vec<f32>) {
    let mut tuner = FineTuner::new(model, adapters, Method::Skip2Lora, Backend::Blocked, 8);
    let mut cache = skip2lora::cache::SkipCache::new(data.len());
    let mut timer = PhaseTimer::new();
    let mut rng = Rng::new(4242);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let idx = rng.sample_with_replacement(data.len(), 8);
        tuner.forward_cached(data, &idx, &mut cache, &mut timer);
        losses.push(tuner.backward(&mut timer));
        tuner.update(0.05, &mut timer);
    }
    (tuner.adapters, losses)
}

/// N fine-tune threads + a serving batcher over ONE `Arc<Mlp>` produce
/// exactly (bit-for-bit) what N runs over N private backbone clones
/// produce. This is the acceptance criterion for deleting the per-job
/// backbone clone.
#[test]
fn shared_arc_matches_cloned_backbone_bit_for_bit() {
    const N_WORKERS: u64 = 4;
    let mut rng = Rng::new(11);
    let shared = Arc::new(Mlp::new(&mut rng, cfg()));

    // per-worker deterministic inputs: adapters + data
    let jobs: Vec<(AdapterSet, Dataset)> = (0..N_WORKERS)
        .map(|t| {
            let mut arng = Rng::new(100 + t);
            (
                AdapterSet::new(&mut arng, &cfg(), AdapterTopology::Skip),
                clustered(200 + t, 40),
            )
        })
        .collect();

    // reference: the OLD discipline — every job trains against its own
    // deep clone of the backbone, serially
    let reference: Vec<(AdapterSet, Vec<f32>)> = jobs
        .iter()
        .map(|(adapters, data)| {
            let private: Mlp = (*shared).clone();
            finetune(private, adapters.clone(), data, 60)
        })
        .collect();

    // new discipline: all jobs run CONCURRENTLY against the one shared
    // Arc, while a serving batcher (a `testkit::stress` observer)
    // hammers the same backbone until every fine-tune worker finishes
    let registry = Arc::new(AdapterRegistry::new());
    let scfg = StressConfig { workers: N_WORKERS as usize, ops: 60, observers: 1, seed: 0xB17 };
    let report = stress::run(
        &scfg,
        &jobs,
        |ctx, jobs: &Vec<(AdapterSet, Dataset)>| {
            let (adapters, data) = &jobs[ctx.index];
            finetune(Arc::clone(&shared), adapters.clone(), data, ctx.ops)
        },
        |ctx, _| {
            // concurrent read pressure: serve micro-batches from the Arc
            // for AT LEAST 200 rounds, and for as long as any fine-tune
            // worker is still running — the overlap is the point
            let frozen = FrozenBackbone::new(Arc::clone(&shared), Backend::Blocked, 8);
            let mut batcher = MicroBatcher::new(frozen, Arc::clone(&registry));
            let mut rng = Rng::new(77);
            let mut out = Vec::new();
            let mut served = 0usize;
            let mut round = 0u64;
            while round < 200 || ctx.workers_live() {
                for t in 0..N_WORKERS {
                    let x: Vec<f32> = (0..10).map(|_| rng.normal()).collect();
                    batcher.submit(BatchRequest { tenant: t, id: round, x, label: None });
                }
                served += batcher.flush(&mut out);
                out.clear();
                round += 1;
            }
            served
        },
    );
    assert!(report.observers[0] >= 200 * N_WORKERS as usize);
    let results: Vec<(AdapterSet, Vec<f32>)> = report.workers;

    // bit-identical trajectories: losses AND final adapter weights
    for (t, ((got_ad, got_losses), (want_ad, want_losses))) in
        results.iter().zip(&reference).enumerate()
    {
        assert_eq!(got_losses, want_losses, "worker {t}: loss trajectory diverged");
        for (a, b) in got_ad.adapters.iter().zip(&want_ad.adapters) {
            assert_eq!(a.wa.data, b.wa.data, "worker {t}: W_A diverged");
            assert_eq!(a.wb.data, b.wb.data, "worker {t}: W_B diverged");
        }
    }

    // and the backbone is still the one everyone started with — no CoW
    // split happened anywhere (frozen methods never take &mut), so after
    // workers and batcher dropped their handles ours is the last one
    assert_eq!(Arc::strong_count(&shared), 1);
}

/// Serving logits from the shared batcher are bit-identical to logits
/// computed through a FineTuner holding the same Arc — the two code paths
/// (`apply_skip_adapters_row` fan-out vs `predict_alloc`) read the same
/// weights and must agree while fine-tunes run concurrently.
#[test]
fn concurrent_serving_is_stable_under_finetune_load() {
    let mut rng = Rng::new(31);
    let shared = Arc::new(Mlp::new(&mut rng, cfg()));
    let registry = Arc::new(AdapterRegistry::new());

    // publish non-trivial adapters for tenant 0
    let mut adapters = AdapterSet::new(&mut rng, &cfg(), AdapterTopology::Skip);
    for ad in adapters.adapters.iter_mut() {
        for v in ad.wb.data.iter_mut() {
            *v = 0.1 * rng.normal();
        }
    }
    registry.publish(0, adapters.adapters.clone());

    let x: Vec<f32> = (0..10).map(|_| rng.normal()).collect();
    let expected = {
        let tuner = FineTuner::new(
            Arc::clone(&shared),
            adapters.clone(),
            Method::SkipLora,
            Backend::Blocked,
            1,
        );
        tuner.predict_alloc(&Mat::from_vec(1, 10, x.clone())).row(0).to_vec()
    };

    // background fine-tune churn on other tenants' adapters over the SAME
    // backbone Arc (stress workers), while the observer asserts tenant
    // 0's serving logits never waver
    let scfg = StressConfig { workers: 3, ops: 40, observers: 1, seed: 0xC4A0 };
    stress::run(
        &scfg,
        &(),
        |ctx, _| {
            let t = ctx.index as u64 + 1;
            let data = clustered(900 + t, 30);
            let mut arng = Rng::new(t);
            let adapters = AdapterSet::new(&mut arng, &cfg(), AdapterTopology::Skip);
            let _ = finetune(Arc::clone(&shared), adapters, &data, ctx.ops);
        },
        |ctx, _| {
            let frozen = FrozenBackbone::new(Arc::clone(&shared), Backend::Blocked, 4);
            let mut batcher = MicroBatcher::new(frozen, Arc::clone(&registry));
            // at least 100 repetitions, and keep serving while ANY
            // fine-tune thread is still churning over the same Arc
            // (logits snapshot per flush — the staging matrix is reused)
            let mut served: Vec<Vec<f32>> = Vec::new();
            let mut i = 0u64;
            while i < 100 || ctx.workers_live() {
                let mut out = Vec::new();
                batcher.submit(BatchRequest { tenant: 0, id: i, x: x.clone(), label: None });
                batcher.flush(&mut out);
                served.push(batcher.last_logits().row(out[0].row).to_vec());
                i += 1;
            }
            // same serving path + same frozen weights => bit-identical
            // across every repetition, whatever the fine-tune threads do
            for logits in &served {
                assert_eq!(logits, &served[0], "serving logits drifted under load");
            }
            // and the serving path agrees with the training-side predict
            // path (different kernel shapes: float tolerance, not bits)
            for (a, b) in served[0].iter().zip(&expected) {
                assert!((a - b).abs() < 1e-4, "serve {a} vs predict {b}");
            }
        },
    );
}
