//! Property-based invariant sweeps (mini-proptest; see testkit::prop):
//! randomized shapes/inputs over the tensor kernels, cache semantics,
//! batch sampler, compute-type routing, and the Skip-Cache exactness
//! invariant at random model sizes.

use skip2lora::cache::{BoundedSkipCache, CacheEntry, SkipCache};
use skip2lora::data::sampler::{BatchSampler, SamplingMode};
use skip2lora::data::Dataset;
use skip2lora::method::Method;
use skip2lora::model::{Mlp, MlpConfig};
use skip2lora::tensor::{ops, ops::Backend, Mat};
use skip2lora::testkit::prop::{check, gen, PropConfig};
use skip2lora::train::FineTuner;
use skip2lora::util::rng::Rng;
use skip2lora::util::timer::PhaseTimer;

fn close(a: &Mat, b: &Mat, tol: f32) -> Result<(), String> {
    if a.shape() != b.shape() {
        return Err(format!("shape {:?} vs {:?}", a.shape(), b.shape()));
    }
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("elem {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[test]
fn prop_blocked_kernels_match_scalar() {
    check("blocked==scalar", PropConfig { cases: 60, ..Default::default() }, |rng| {
        let (r, k, c) = (
            gen::usize_in(rng, 1, 33),
            gen::usize_in(rng, 1, 300),
            gen::usize_in(rng, 1, 120),
        );
        let a = gen::sparse_mat(rng, r, k, 0.3);
        let b = gen::mat(rng, k, c);
        let mut o1 = Mat::zeros(r, c);
        let mut o2 = Mat::zeros(r, c);
        ops::matmul_naive(&a, &b, &mut o1);
        ops::matmul_blocked(&a, &b, &mut o2);
        close(&o1, &o2, 1e-4)?;

        // transposed variants on batch-shaped inputs
        let x = gen::sparse_mat(rng, r, k, 0.5);
        let gy = gen::mat(rng, r, c);
        let mut g1 = Mat::zeros(k, c);
        let mut g2 = Mat::zeros(k, c);
        ops::matmul_at_b_naive(&x, &gy, &mut g1);
        ops::matmul_at_b_blocked(&x, &gy, &mut g2);
        close(&g1, &g2, 1e-4)?;

        let w = gen::mat(rng, k, c);
        let mut h1 = Mat::zeros(r, k);
        let mut h2 = Mat::zeros(r, k);
        ops::matmul_a_bt_naive(&gy, &w, &mut h1);
        ops::matmul_a_bt_blocked(&gy, &w, &mut h2);
        close(&h1, &h2, 1e-4)
    });
}

#[test]
fn prop_full_cache_is_exact_map() {
    // the full store behaves as a partial map: insert-then-lookup returns
    // exactly the inserted entry; never evicts; occupancy == distinct keys
    check("full-cache map", PropConfig { cases: 40, ..Default::default() }, |rng| {
        let n = gen::usize_in(rng, 1, 200);
        let mut c = SkipCache::new(n);
        let mut shadow: Vec<Option<f32>> = vec![None; n];
        for _ in 0..500 {
            let i = rng.below(n);
            if rng.f32() < 0.5 {
                let v = rng.normal();
                c.insert(i, CacheEntry { xs: vec![vec![v; 3]], c_n: vec![v] });
                shadow[i] = Some(v);
            } else {
                match (c.lookup(i), shadow[i]) {
                    (Some(e), Some(v)) => {
                        if e.c_n[0] != v {
                            return Err(format!("stale value at {i}"));
                        }
                    }
                    (None, None) => {}
                    (a, b) => {
                        return Err(format!(
                            "presence mismatch at {i}: cache={} shadow={}",
                            a.is_some(),
                            b.is_some()
                        ))
                    }
                }
            }
        }
        let distinct = shadow.iter().filter(|s| s.is_some()).count();
        if c.occupied() != distinct {
            return Err(format!("occupancy {} != {}", c.occupied(), distinct));
        }
        Ok(())
    });
}

#[test]
fn prop_bounded_cache_never_exceeds_capacity_and_serves_fresh() {
    check("bounded-cache", PropConfig { cases: 40, ..Default::default() }, |rng| {
        let cap = gen::usize_in(rng, 1, 50);
        let universe = gen::usize_in(rng, 1, 200);
        let mut c = BoundedSkipCache::new(cap);
        let mut latest: Vec<Option<f32>> = vec![None; universe];
        for _ in 0..400 {
            let i = rng.below(universe);
            if rng.f32() < 0.5 {
                let v = rng.normal();
                c.insert(i, CacheEntry { xs: vec![], c_n: vec![v] });
                latest[i] = Some(v);
            } else if let Some(e) = c.lookup(i) {
                // a hit must serve the latest inserted value (never stale)
                match latest[i] {
                    Some(v) if e.c_n[0] == v => {}
                    _ => return Err(format!("stale/unknown value for {i}")),
                }
            }
            if c.len() > cap {
                return Err(format!("len {} > cap {cap}", c.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sampler_indices_in_range_and_deterministic() {
    check("sampler", PropConfig { cases: 30, ..Default::default() }, |rng| {
        let n = gen::usize_in(rng, 1, 500);
        let b = gen::usize_in(rng, 1, n.min(64) + 1).min(n).max(1);
        for mode in [SamplingMode::WithReplacement, SamplingMode::Shuffled] {
            let seed = rng.next_u64();
            let mut s1 = BatchSampler::new(n, b, mode);
            let mut s2 = BatchSampler::new(n, b, mode);
            let (mut r1, mut r2) = (Rng::new(seed), Rng::new(seed));
            let (mut i1, mut i2) = (Vec::new(), Vec::new());
            for _ in 0..5 {
                s1.next_batch(&mut r1, &mut i1);
                s2.next_batch(&mut r2, &mut i2);
                if i1 != i2 {
                    return Err("nondeterministic".into());
                }
                if i1.iter().any(|&i| i >= n) {
                    return Err("index out of range".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_method_routing_consistency() {
    // structural invariants of the Table 1 routing for any depth
    check("method routing", PropConfig { cases: 30, ..Default::default() }, |rng| {
        let n = gen::usize_in(rng, 1, 8);
        for m in Method::ALL {
            let fc = m.fc_types(n);
            let lo = m.lora_types(n);
            if fc.len() != n || lo.len() != n {
                return Err(format!("{m}: wrong arity at n={n}"));
            }
            // cache-compatible methods must freeze every non-last FC layer
            if m.cache_compatible() {
                for (k, t) in fc.iter().enumerate().take(n - 1) {
                    if t.is_trained() || t.computes_gx() {
                        return Err(format!("{m}: layer {k} not frozen ({t:?})"));
                    }
                }
            }
            // the first layer never computes gx (nothing consumes it)
            if fc[0].computes_gx() {
                return Err(format!("{m}: first layer computes gx"));
            }
            if lo[0].computes_gx() {
                return Err(format!("{m}: first adapter computes gx"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_skip2_cache_exactness_random_models() {
    // For random widths/batch sizes, the cached path must reproduce the
    // uncached Skip-LoRA trajectory (losses within float tolerance).
    check("skip2 exactness", PropConfig { cases: 8, ..Default::default() }, |rng| {
        let d_in = gen::usize_in(rng, 4, 40);
        let hidden = gen::usize_in(rng, 4, 32);
        let classes = gen::usize_in(rng, 2, 6);
        let batch = gen::usize_in(rng, 2, 12);
        let n_samples = gen::usize_in(rng, batch, 60).max(batch);

        let cfg = MlpConfig {
            dims: vec![d_in, hidden, hidden, classes],
            rank: 2,
            batch_norm: true,
        };
        let mut mrng = Rng::new(rng.next_u64());
        let model = std::sync::Arc::new(Mlp::new(&mut mrng, cfg.clone()));
        let adapters =
            skip2lora::model::AdapterSet::new(&mut mrng, &cfg, Method::SkipLora.topology());
        let data = Dataset {
            x: gen::mat(rng, n_samples, d_in),
            labels: gen::labels(rng, n_samples, classes),
            n_classes: classes,
        };

        let mut a = FineTuner::new(
            std::sync::Arc::clone(&model),
            adapters.clone(),
            Method::SkipLora,
            Backend::Blocked,
            batch,
        );
        let mut b = FineTuner::new(model, adapters, Method::Skip2Lora, Backend::Blocked, batch);
        let mut cache = SkipCache::new(n_samples);
        let mut timer = PhaseTimer::new();
        let seed = rng.next_u64();
        let (mut ra, mut rb) = (Rng::new(seed), Rng::new(seed));
        for step in 0..15 {
            let ia = ra.sample_with_replacement(n_samples, batch);
            let ib = rb.sample_with_replacement(n_samples, batch);
            a.load_batch(&data, &ia);
            a.forward(&mut timer);
            let la = a.backward(&mut timer);
            a.update(0.05, &mut timer);
            b.forward_cached(&data, &ib, &mut cache, &mut timer);
            let lb = b.backward(&mut timer);
            b.update(0.05, &mut timer);
            if (la - lb).abs() > 1e-4 * (1.0 + la.abs()) {
                return Err(format!("step {step}: loss {la} vs {lb}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_softmax_ce_bounds() {
    check("ce bounds", PropConfig { cases: 50, ..Default::default() }, |rng| {
        let b = gen::usize_in(rng, 1, 16);
        let m = gen::usize_in(rng, 2, 10);
        let logits = gen::mat(rng, b, m);
        let labels = gen::labels(rng, b, m);
        let mut g = Mat::zeros(b, m);
        let loss = skip2lora::nn::loss::softmax_ce(&logits, &labels, &mut g);
        if !loss.is_finite() || loss < 0.0 {
            return Err(format!("bad loss {loss}"));
        }
        // gradient rows sum to ~0
        for i in 0..b {
            let s: f32 = g.row(i).iter().sum();
            if s.abs() > 1e-5 {
                return Err(format!("row {i} grad sum {s}"));
            }
        }
        Ok(())
    });
}
