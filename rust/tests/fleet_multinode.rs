//! Multi-node fleet over loopback TCP: rendezvous routing, a mid-traffic
//! node decommission with live tenant migration, and the acceptance
//! criteria from DESIGN.md §12 —
//!
//! * post-migration serving is BIT-IDENTICAL to an unkilled in-process
//!   oracle fed the same per-tenant streams,
//! * the books balance: completions == admissions − typed rejections,
//!   nothing accepted is ever lost (including requests queued on the
//!   victim node at the moment it is decommissioned),
//! * the fleet-merged observability document validates and its counters
//!   equal the sum of the per-node snapshots.

use skip2lora::data::Dataset;
use skip2lora::fleet::FleetRouter;
use skip2lora::model::MlpConfig;
use skip2lora::net::{Admission, NodeClient, NodeServer};
use skip2lora::obs::snapshot::validate as validate_obs;
use skip2lora::serve::server::RejectReason;
use skip2lora::serve::{FleetServer, Request, Response, ServeConfig};
use skip2lora::tensor::ops::Backend;
use skip2lora::tensor::Mat;
use skip2lora::train::trainer::pretrain;
use skip2lora::util::json::Json;
use skip2lora::util::rng::Rng;

const N_TENANTS: u64 = 9;
/// feedback rounds per tenant — enough past `buffer_target` that every
/// drifted tenant fine-tunes and PUBLISHES before the node dies, so the
/// migration has real trained state to move
const ROUNDS: usize = 36;
const PROBES: usize = 20;

fn clustered(seed: u64, n: usize, shift: f32) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Mat::zeros(n, 8);
    let mut labels = Vec::new();
    for i in 0..n {
        let c = i % 3;
        for j in 0..8 {
            let base = if j % 3 == c { 2.0 } else { 0.0 };
            *x.at_mut(i, j) = base + shift + 0.3 * rng.normal();
        }
        labels.push(c);
    }
    Dataset {
        x,
        labels,
        n_classes: 3,
    }
}

fn serve_config() -> ServeConfig {
    ServeConfig {
        batch_capacity: 16,
        window: 20,
        accuracy_threshold: 0.7,
        buffer_target: 30,
        epochs: 20,
        lr: 0.05,
        train_batch: 15,
        // inline fine-tunes: the pump clock fully determines execution,
        // which is what makes the cross-placement oracle comparison exact
        workers: 0,
        ..Default::default()
    }
}

fn new_server(backbone: &skip2lora::model::Mlp) -> FleetServer {
    FleetServer::new(backbone.clone(), serve_config())
}

fn drifted(t: u64) -> bool {
    t % 3 != 0
}

/// Tenant t's private stream: drifted for 2 of every 3 tenants so that
/// fine-tunes actually trigger and migrated state MATTERS.
fn tenant_stream(t: u64) -> Dataset {
    let shift = if drifted(t) { 2.5 } else { 0.0 };
    clustered(1000 + t, ROUNDS, shift)
}

#[test]
fn kill_migrate_and_serve_bit_identical_with_balanced_books() {
    let cfg = MlpConfig {
        dims: vec![8, 12, 12, 3],
        rank: 2,
        batch_norm: true,
    };
    let backbone = pretrain(cfg, &clustered(0, 120, 0.0), 50, 0.05, 1, Backend::Blocked);

    // three wire-served nodes + the unkilled in-process oracle
    let mut nodes = Vec::new();
    for _ in 0..3 {
        nodes.push(Some(
            NodeServer::spawn(new_server(&backbone), "127.0.0.1:0").unwrap(),
        ));
    }
    let mut oracle = new_server(&backbone);

    let mut router = FleetRouter::new();
    for (i, n) in nodes.iter().enumerate() {
        router
            .add_node(&format!("node{i}"), &n.as_ref().unwrap().addr().to_string())
            .unwrap();
    }
    assert_eq!(router.alive_count(), 3);

    let streams: Vec<Dataset> = (0..N_TENANTS).map(tenant_stream).collect();

    let mut admitted = 0u64; // fleet admissions (Queued responses)
    let mut completed = 0u64; // fleet completions, wherever they surface
    let mut sends = 0usize;

    // ---- phase 1: labelled feedback across the healthy 3-node fleet;
    // the oracle sees the IDENTICAL per-tenant streams and pump cadence
    for round in 0..ROUNDS {
        for t in 0..N_TENANTS {
            let x = streams[t as usize].x.row(round).to_vec();
            let label = streams[t as usize].labels[round];
            match router.feedback(t, x.clone(), label as u32).unwrap() {
                Admission::Queued { .. } => admitted += 1,
                Admission::Rejected(r) => panic!("unexpected rejection: {r:?}"),
            }
            match oracle.handle(t, Request::Feedback(x, label)) {
                Response::Queued { .. } => {}
                other => panic!("oracle rejected: {other:?}"),
            }
            sends += 1;
            if sends % 16 == 0 {
                completed += router.pump_all().unwrap().len() as u64;
                oracle.pump();
            }
        }
    }
    completed += router.pump_drain_all().unwrap().len() as u64;
    oracle.pump_until_drained();

    // drifted tenants must have actually published trained adapters —
    // otherwise the migration below would be moving nothing. Version
    // NUMBERS are per-server (globally monotone counters), but the
    // per-tenant adaptation count is placement-independent.
    for t in 0..N_TENANTS {
        let idx = router.route(t).unwrap();
        let (fleet_v, fleet_rounds) = nodes[idx]
            .as_ref()
            .unwrap()
            .with_server(|s| (s.tenant_version(t), s.tenant_adaptations(t)));
        assert_eq!(
            fleet_rounds,
            oracle.tenant_adaptations(t),
            "tenant {t}: fleet and oracle disagree on adaptation count"
        );
        if drifted(t) {
            assert!(fleet_v > 0, "drifted tenant {t} never published");
            assert!(oracle.tenant_version(t) > 0);
        }
    }

    // ---- kill node 1 mid-traffic. First stage some unpumped Predicts
    // on the victim so the drain has real in-flight work to flush —
    // proving "zero lost accepted requests" through the migration.
    let victim = 1usize;
    let victim_tenants = router.tenants_on(victim);
    assert!(
        !victim_tenants.is_empty(),
        "rendezvous placed no tenants on the victim?"
    );
    let mut staged = 0u64;
    for &t in &victim_tenants {
        match router.predict(t, streams[t as usize].x.row(0).to_vec()).unwrap() {
            Admission::Queued { .. } => {
                admitted += 1;
                staged += 1;
            }
            other => panic!("{other:?}"),
        }
    }

    let report = router.decommission(victim).unwrap();
    assert_eq!(report.drained.queued_at_start as u64, staged);
    assert_eq!(report.drained.completions.len() as u64, staged);
    completed += report.drained.completions.len() as u64;
    assert!(!router.is_alive(victim));
    assert_eq!(router.alive_count(), 2);

    // exactly the drifted victims migrated; clean ones had no published
    // adapters and re-home statelessly
    let expect_moved: Vec<u64> = victim_tenants.iter().copied().filter(|&t| drifted(t)).collect();
    let moved: Vec<u64> = report.migrated.iter().map(|&(t, _, _)| t).collect();
    assert_eq!(moved, expect_moved, "unexpected migration set");
    assert!(!moved.is_empty(), "no drifted tenant lived on the victim");
    for &(tenant, dst, version) in &report.migrated {
        assert!(router.is_alive(dst));
        assert_ne!(dst, victim);
        assert!(version > 0, "tenant {tenant}: import allocated no version");
    }
    let skipped: Vec<u64> =
        victim_tenants.iter().copied().filter(|&t| !drifted(t)).collect();
    assert_eq!(report.skipped, skipped);

    // the drained node answers with a TYPED rejection, not a hang/panic
    let victim_addr = nodes[victim].as_ref().unwrap().addr().to_string();
    let mut direct = NodeClient::connect(&victim_addr).unwrap();
    match direct.predict(victim_tenants[0], streams[0].x.row(0).to_vec()) {
        Ok(Admission::Rejected(RejectReason::Draining)) => {}
        other => panic!("expected typed Draining rejection, got {other:?}"),
    }
    drop(direct);

    // actually kill it: shutdown returns the inner server, whose queue
    // must be empty (the drain completed everything it had accepted)
    let dead = nodes[victim].take().unwrap().shutdown();
    assert_eq!(dead.queued(), 0, "drain left requests behind");
    assert!(dead.is_draining());

    // ---- phase 2: serving CONTINUES through the router — predictions
    // for every tenant, bit-identical to the oracle that never lost a
    // node. Predicts are label-free, so neither side's adaptation state
    // advances and the comparison is pure.
    let probes = clustered(777, PROBES, 1.0);
    for t in 0..N_TENANTS {
        for p in 0..PROBES {
            let x = probes.x.row(p).to_vec();
            match router.predict(t, x.clone()).unwrap() {
                Admission::Queued { .. } => admitted += 1,
                other => panic!("probe rejected: {other:?}"),
            }
            let done = router.pump_drain_all().unwrap();
            assert_eq!(done.len(), 1);
            completed += 1;
            let fleet_pred = done[0].prediction;

            match oracle.handle(t, Request::Predict(x)) {
                Response::Queued { .. } => {}
                other => panic!("oracle probe rejected: {other:?}"),
            }
            let oracle_done = oracle.pump_until_drained();
            assert_eq!(oracle_done.len(), 1);
            assert_eq!(
                fleet_pred, oracle_done[0].prediction,
                "tenant {t} probe {p}: fleet diverged from the unkilled oracle"
            );

            // migrated tenants are served by a SURVIVING node
            let serving = router.route(t).unwrap();
            assert!(router.is_alive(serving));
        }
    }

    // ---- books balance: every accepted request completed exactly once
    assert_eq!(
        admitted,
        (N_TENANTS as usize * ROUNDS) as u64 + staged + (N_TENANTS as usize * PROBES) as u64
    );
    assert_eq!(
        completed, admitted,
        "completions must equal admissions (zero lost, zero duplicated)"
    );

    for n in nodes.into_iter().flatten() {
        n.shutdown();
    }
    oracle.shutdown();
}

#[test]
fn fleet_merged_obs_validates_and_counters_sum_over_the_wire() {
    let cfg = MlpConfig {
        dims: vec![8, 12, 12, 3],
        rank: 2,
        batch_norm: true,
    };
    let backbone = pretrain(cfg, &clustered(0, 120, 0.0), 50, 0.05, 1, Backend::Blocked);

    let mut nodes = Vec::new();
    for _ in 0..3 {
        nodes.push(NodeServer::spawn(new_server(&backbone), "127.0.0.1:0").unwrap());
    }
    let mut router = FleetRouter::new();
    for (i, n) in nodes.iter().enumerate() {
        router
            .add_node(&format!("node{i}"), &n.addr().to_string())
            .unwrap();
    }

    // spread real traffic so every node has non-trivial counters
    for t in 0..12u64 {
        let data = tenant_stream(t);
        for i in 0..16 {
            let x = data.x.row(i).to_vec();
            match router.feedback(t, x, data.labels[i] as u32).unwrap() {
                Admission::Queued { .. } => {}
                other => panic!("{other:?}"),
            }
        }
    }
    router.pump_drain_all().unwrap();

    // per-node snapshots, straight off the wire
    let mut per_node = Vec::new();
    for n in &nodes {
        let mut c = NodeClient::connect(&n.addr().to_string()).unwrap();
        per_node.push(c.observe().unwrap());
    }

    // the router's merged fleet document re-validates against the schema
    let merged = router.fleet_obs().unwrap();
    validate_obs(&merged).expect("fleet-merged document must validate");

    // counters in the merged document equal the SUM over nodes
    let count = |doc: &Json, key: &str| -> f64 {
        doc.get("serve")
            .and_then(|s| s.get(key))
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("missing serve.{key}"))
    };
    for key in ["feedbacks", "predicts", "batches", "batched_rows", "adaptations"] {
        let sum: f64 = per_node
            .iter()
            .map(|t| count(&Json::parse(t).unwrap(), key))
            .sum();
        assert_eq!(
            count(&merged, key),
            sum,
            "fleet serve.{key} must be the exact per-node sum"
        );
    }
    assert_eq!(
        merged.get("nodes").and_then(|v| v.as_f64()),
        Some(3.0),
        "merged document records the node count"
    );

    // the skew probe sees every node's registry population
    let skew = router.skew().unwrap();
    assert_eq!(skew.per_node_tenants.len(), 3);
    assert!(skew.max_over_mean >= 1.0);

    for n in nodes {
        n.shutdown();
    }
}
