//! The zero-alloc serving proof: with a counting allocator installed as
//! this binary's global allocator, a warm `MicroBatcher::flush` must
//! perform EXACTLY zero heap allocations — every buffer on the hot path
//! (request staging, registry snapshot batch, tenant-group gathers, rank
//! workspace, logits staging, packed weight panels, response vector) is
//! preallocated and reused.
//!
//! Since PR 6 the measured flush runs with FULL OBSERVABILITY LIVE: the
//! per-stage timers enabled and a flight recorder attached. The trace
//! ring is preallocated and events are plain `Copy` structs, so tracing
//! must not cost a single allocation either (DESIGN.md §11).
//!
//! Kept to a single #[test] on purpose: the counter is process-global,
//! so a second allocating test running concurrently in this binary would
//! turn the exact-zero assertion flaky.

use std::sync::Arc;

use skip2lora::model::{Mlp, MlpConfig};
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::obs::trace::FlightRecorder;
use skip2lora::serve::batcher::{BatchRequest, FrozenBackbone, MicroBatcher};
use skip2lora::serve::lanes::LaneSet;
use skip2lora::serve::registry::AdapterRegistry;
use skip2lora::tensor::ops::Backend;
use skip2lora::testkit::{alloc_counter, CountingAlloc};
use skip2lora::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn cfg() -> MlpConfig {
    MlpConfig { dims: vec![16, 24, 24, 5], rank: 4, batch_norm: true }
}

#[test]
fn warm_flush_performs_zero_allocations() {
    let mut rng = Rng::new(0xA110C);
    let cfg = cfg();
    let backbone = Arc::new(Mlp::new(&mut rng, cfg.clone()));
    let registry = Arc::new(AdapterRegistry::new());
    // 5 published tenants with non-trivial adapters; tenant 9 stays bare
    for t in 0..5u64 {
        let mut ads: Vec<LoraAdapter> = (0..3)
            .map(|k| LoraAdapter::new(&mut rng, cfg.dims[k], 4, 5))
            .collect();
        for ad in ads.iter_mut() {
            for v in ad.wb.data.iter_mut() {
                *v = 0.1 * rng.normal();
            }
        }
        registry.publish(t, ads);
    }

    let capacity = 8usize;
    let fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Packed, capacity);
    let mut batcher = MicroBatcher::new(fb, Arc::clone(&registry));
    // full observability stays ON for the measured round: stage timers
    // plus a live flight recorder whose ring is sized to hold every event
    // this test emits (no overwrites — dropped must stay 0)
    batcher.set_stage_timing(true);
    let mut recorder = FlightRecorder::new(256, true);

    // the measured flush must cover every hot-path branch: tenant groups
    // of size 1 and 3, a bare (unpublished) tenant, and feedback rows
    // (whose x moves back out — a move, not an allocation)
    let tenants = [0u64, 1, 0, 2, 9, 1, 0, 3];
    let labels = [None, Some(1), None, None, Some(0), None, Some(4), None];
    let make_requests = |rng: &mut Rng| -> Vec<BatchRequest> {
        tenants
            .iter()
            .zip(labels)
            .enumerate()
            .map(|(i, (&tenant, label))| BatchRequest {
                tenant,
                id: i as u64,
                x: (0..16).map(|_| rng.normal()).collect(),
                label,
            })
            .collect()
    };

    let mut out = Vec::with_capacity(capacity);
    // warm-up: sizes every reusable buffer (staging, snapshot batch,
    // gather scratch, packed panels, the VecDeque ring, `out`)
    for _ in 0..3 {
        for req in make_requests(&mut rng) {
            batcher.try_submit(req).expect("under the bound by construction");
        }
        out.clear();
        assert_eq!(batcher.flush_traced(&mut out, Some(&mut recorder)), tenants.len());
    }

    // measured round: requests are built and queued BEFORE the window —
    // submit-side allocation (the request's own x vector) is the
    // caller's, the flush itself owns everything else
    let reqs = make_requests(&mut rng);
    for req in reqs {
        batcher.try_submit(req).expect("under the bound");
    }
    out.clear();

    let before = alloc_counter::allocations();
    let served = batcher.flush_traced(&mut out, Some(&mut recorder));
    let after = alloc_counter::allocations();

    assert_eq!(served, tenants.len());
    assert_eq!(out.len(), tenants.len());
    assert_eq!(
        after - before,
        0,
        "warm flush (with stage timers + flight recorder live) allocated {} time(s) — \
         the zero-alloc steady state regressed",
        after - before
    );

    // the observability layer actually observed: 4 flushes timed, events
    // recorded (FlushStart + per-group FanoutTenant + FlushEnd each), and
    // the preallocated ring never overflowed
    assert_eq!(batcher.stages().flushes(), 4);
    assert!(batcher.stages().sum_stage_ns() > 0, "stage timers recorded nothing");
    assert!(!recorder.is_empty(), "flight recorder captured no events");
    assert_eq!(recorder.dropped(), 0, "trace ring overflowed — capacity undersized");

    // sanity: the instrument actually counts (a fresh Vec must register)
    let before = alloc_counter::allocations();
    let probe: Vec<u8> = Vec::with_capacity(1024);
    std::hint::black_box(&probe);
    let after = alloc_counter::allocations();
    assert!(after > before, "counting allocator is not installed/working");

    // responses carried the feedback x's back by move, predicts carry none
    for (resp, label) in out.iter().zip(labels) {
        assert_eq!(resp.label, label);
        assert_eq!(resp.x.is_some(), label.is_some());
    }

    // ------------------------------------------------------------------
    // per-lane zero-alloc (DESIGN.md §13): the SAME guarantee must hold
    // for every lane of a multi-lane set — each lane owns its own
    // scratch, stage timers, and flight recorder, all live during the
    // measured flush. (The parallel drive's thread spawn is the
    // documented cost of going wide; the per-lane flush path itself
    // must stay allocation-free, which is what `flush_lane` measures.)
    // ------------------------------------------------------------------
    let mut lanes = LaneSet::new(2, 256, true, |_| {
        let fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Packed, capacity);
        let mut b = MicroBatcher::new(fb, Arc::clone(&registry));
        b.set_stage_timing(true);
        b
    });
    // tenants 0..3 + bare 9 hash across both lanes; assert both see work
    let mut lane_out = Vec::with_capacity(2 * capacity);
    for round in 0..3 {
        for req in make_requests(&mut rng) {
            lanes.try_submit(req).expect("lane queue bound is ample");
        }
        if round == 0 {
            assert!(
                (0..2).all(|l| lanes.pending_lane(l) > 0),
                "fixture tenants must exercise BOTH lanes"
            );
        }
        lane_out.clear();
        // warm each lane's staging, gather scratch, packed panels, ring
        while lanes.pending() > 0 {
            for l in 0..2 {
                if lanes.pending_lane(l) > 0 {
                    lanes.flush_lane(l, &mut lane_out);
                }
            }
        }
    }

    for req in make_requests(&mut rng) {
        lanes.try_submit(req).expect("under the bound");
    }
    lane_out.clear();
    for lane in 0..2 {
        let queued = lanes.pending_lane(lane);
        assert!(queued > 0, "lane {lane} has nothing to flush");
        let before = alloc_counter::allocations();
        let served = lanes.flush_lane(lane, &mut lane_out);
        let after = alloc_counter::allocations();
        assert_eq!(served, queued);
        assert_eq!(
            after - before,
            0,
            "lane {lane} warm flush (stage timers + per-lane recorder live) \
             allocated {} time(s)",
            after - before
        );
        assert!(!lanes.recorder(lane).is_empty(), "lane {lane} recorder captured nothing");
        assert_eq!(lanes.recorder(lane).dropped(), 0, "lane {lane} trace ring overflowed");
        assert!(lanes.batcher(lane).stages().sum_stage_ns() > 0);
    }
    assert!(lanes.balanced(), "lane books must close after the measured round");

    // the lane-merge fold is fixed-array arithmetic — merging warm stage
    // snapshots must not allocate either (fleet aggregation runs hot)
    let mut acc = lanes.batcher(0).stages().clone();
    let before = alloc_counter::allocations();
    acc.merge(lanes.batcher(1).stages());
    let after = alloc_counter::allocations();
    assert_eq!(after - before, 0, "FlushStages::merge allocated on warm snapshots");
    assert_eq!(
        acc.flushes(),
        lanes.total_batches(),
        "merged fold must count every lane flush"
    );
}
