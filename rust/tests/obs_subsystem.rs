//! End-to-end observability tests (DESIGN.md §11): the per-stage flush
//! attribution must reconcile against the measured flush total, the
//! `Request::Observe` snapshot must satisfy its own `skip2lora/obs/v1`
//! validator, pump-denominated throughput must be exactly deterministic,
//! the bounded tenant rollup table must keep heavy hitters, the flight
//! recorder's overwrite policy must surface drops, and a real
//! drift-triggered fine-tune must land its forward/backward/update
//! attribution (the paper's Tables 6/7 decomposition).

use std::sync::Arc;

use skip2lora::data::Dataset;
use skip2lora::model::{Mlp, MlpConfig};
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::obs::snapshot;
use skip2lora::obs::ObsConfig;
use skip2lora::serve::batcher::{BatchRequest, FrozenBackbone, MicroBatcher};
use skip2lora::serve::registry::AdapterRegistry;
use skip2lora::serve::{FleetServer, Request, Response, ServeConfig};
use skip2lora::tensor::ops::Backend;
use skip2lora::tensor::Mat;
use skip2lora::train::trainer::pretrain;
use skip2lora::util::rng::Rng;

/// Same 3-cluster synthetic data the serve unit tests use.
fn clustered(seed: u64, n: usize, shift: f32) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Mat::zeros(n, 8);
    let mut labels = Vec::new();
    for i in 0..n {
        let c = i % 3;
        for j in 0..8 {
            let base = if j % 3 == c { 2.0 } else { 0.0 };
            *x.at_mut(i, j) = base + shift + 0.3 * rng.normal();
        }
        labels.push(c);
    }
    Dataset { x, labels, n_classes: 3 }
}

fn serve_config(workers: usize, obs: ObsConfig) -> ServeConfig {
    ServeConfig {
        batch_capacity: 16,
        window: 20,
        accuracy_threshold: 0.7,
        buffer_target: 45,
        epochs: 30,
        lr: 0.05,
        train_batch: 15,
        workers,
        obs,
        ..Default::default()
    }
}

fn pretrained_server(workers: usize, obs: ObsConfig) -> FleetServer {
    let cfg = MlpConfig { dims: vec![8, 12, 12, 3], rank: 2, batch_norm: true };
    let backbone = pretrain(cfg, &clustered(0, 120, 0.0), 50, 0.05, 1, Backend::Blocked);
    FleetServer::new(backbone, serve_config(workers, obs))
}

fn drive(server: &mut FleetServer, tenant: u64, data: &Dataset, feedback: bool) {
    for i in 0..data.len() {
        let x = data.x.row(i).to_vec();
        let req = if feedback {
            Request::Feedback(x, data.labels[i])
        } else {
            Request::Predict(x)
        };
        match server.handle(tenant, req) {
            Response::Queued { .. } => {}
            other => panic!("unexpected response {other:?}"),
        }
        if server.queued() >= server.config().batch_capacity {
            server.pump();
        }
    }
    server.pump_until_drained();
}

/// The acceptance criterion: the seven flush stage timers must decompose
/// the measured flush total — their sum lands within 5% of it. Uses a
/// backbone big enough (96-96-96-6) that real GEMM work dwarfs the
/// inter-span gaps the stage timers cannot see.
#[test]
fn flush_stage_sum_reconciles_with_flush_total() {
    let mut rng = Rng::new(0x57A6E5);
    let cfg = MlpConfig { dims: vec![96, 96, 96, 6], rank: 4, batch_norm: true };
    let backbone = Arc::new(Mlp::new(&mut rng, cfg.clone()));
    let registry = Arc::new(AdapterRegistry::new());
    for t in 0..8u64 {
        let ads: Vec<LoraAdapter> = (0..3)
            .map(|k| LoraAdapter::new(&mut rng, cfg.dims[k], 4, 6))
            .collect();
        registry.publish(t, ads);
    }
    let capacity = 32usize;
    let frozen = FrozenBackbone::new(Arc::clone(&backbone), Backend::Packed, capacity);
    let mut batcher = MicroBatcher::new(frozen, Arc::clone(&registry));

    let mut out = Vec::with_capacity(capacity);
    for round in 0..30usize {
        for i in 0..capacity {
            batcher.submit(BatchRequest {
                tenant: ((round + i) % 8) as u64,
                id: i as u64,
                x: (0..96).map(|_| rng.normal()).collect(),
                label: None,
            });
        }
        out.clear();
        assert_eq!(batcher.flush(&mut out), capacity);
    }

    let st = batcher.stages();
    assert_eq!(st.flushes(), 30);
    assert!(st.last_total_ns().is_some());
    let (sum, total) = (st.sum_stage_ns(), st.total_ns());
    assert!(total > 0);
    assert!(
        sum as f64 >= 0.95 * total as f64 && sum as f64 <= 1.02 * total as f64,
        "stage sum {sum} ns does not reconcile with flush total {total} ns \
         ({:.1}% coverage; acceptance band is 95-102%)",
        100.0 * sum as f64 / total as f64
    );
}

#[test]
fn observe_roundtrip_satisfies_own_validator() {
    let mut s = pretrained_server(0, ObsConfig::default());
    for t in 0..5u64 {
        drive(&mut s, t, &clustered(40 + t, 30, 0.0), t % 2 == 0);
    }
    // exercise the persistence events so the snapshot covers them too
    let ck = std::env::temp_dir().join("obs_subsystem_roundtrip.s2l");
    s.persist_to(&ck).expect("persist");
    s.restore_from(&ck).expect("restore");
    std::fs::remove_file(&ck).ok();

    let snap = match s.handle(0, Request::Observe) {
        Response::Observed(snap) => *snap,
        other => panic!("unexpected response {other:?}"),
    };
    let json = snap.to_json();
    let ticks = snapshot::validate(&json).expect("own snapshot must validate");
    assert_eq!(ticks as u64, snap.pump_ticks);
    assert!(snap.pump_ticks > 0);
    assert_eq!(snap.tenants_live, 5);
    assert!(!snap.shards.is_empty());
    assert!(snap.trace.recorded > 0, "traffic must leave a trace");
    // the parse side of the CLI pipe accepts the serialized form too
    assert!(snapshot::validate_text(&json.to_string()).is_ok());
}

/// Satellite: throughput accounting is pump-denominated and therefore
/// exactly reproducible — two identical servers driven identically report
/// bit-identical rows_per_pump, and the quotient is exactly
/// batched_rows / pump_ticks (no wall-clock in the denominator).
#[test]
fn rows_per_pump_is_exactly_deterministic() {
    let run = || {
        let mut s = pretrained_server(0, ObsConfig::default());
        for t in 0..4u64 {
            drive(&mut s, t, &clustered(70 + t, 25, 0.0), false);
        }
        (
            s.metrics.pump_ticks,
            s.metrics.batched_rows,
            s.metrics.rows_per_pump(),
        )
    };
    let (ticks_a, rows_a, rpp_a) = run();
    let (ticks_b, rows_b, rpp_b) = run();
    assert!(ticks_a > 0 && rows_a > 0);
    assert_eq!((ticks_a, rows_a), (ticks_b, rows_b), "identical runs must agree");
    assert_eq!(rpp_a, rpp_b, "rows_per_pump must be bit-identical across runs");
    assert_eq!(rpp_a, rows_a as f64 / ticks_a as f64, "exact quotient, no wall-clock");
    // empty metrics divide to zero, not NaN
    assert_eq!(skip2lora::serve::ServeMetrics::new().rows_per_pump(), 0.0);
}

#[test]
fn tenant_rollups_stay_bounded_and_keep_the_heavy_hitter() {
    let obs = ObsConfig { top_tenants: 4, ..Default::default() };
    let mut s = pretrained_server(0, obs);
    // 11 singleton tenants try to churn the table while tenant 99 stays hot
    let heavy = clustered(5, 40, 0.0);
    drive(&mut s, 99, &heavy, false);
    for t in 0..11u64 {
        drive(&mut s, t, &clustered(200 + t, 4, 0.0), false);
    }
    drive(&mut s, 99, &heavy, false);

    let snap = s.obs_snapshot();
    assert!(snap.tenants.len() <= 4, "rollup table exceeded its bound");
    let top = &snap.tenants[0];
    assert_eq!(top.tenant, 99, "heavy hitter churned out of the rollup table");
    assert!(top.requests >= 80, "space-saving bound must cover the true count");
}

#[test]
fn trace_ring_overwrites_oldest_and_counts_drops() {
    let obs = ObsConfig { trace_capacity: 8, ..Default::default() };
    let mut s = pretrained_server(0, obs);
    drive(&mut s, 1, &clustered(9, 40, 0.0), false);

    let snap = s.obs_snapshot();
    assert_eq!(snap.trace.capacity, 8);
    assert!(snap.trace.recorded > 8, "workload must overflow the tiny ring");
    assert!(snap.trace.dropped > 0, "overwrites must be visible, not silent");
    assert_eq!(
        snap.trace.dropped + snap.trace.tail.len() as u64,
        snap.trace.recorded,
        "held + dropped must account for every event"
    );
    // the tail is the newest events, in order
    for w in snap.trace.tail.windows(2) {
        assert!(w[1].seq == w[0].seq + 1, "tail must be seq-contiguous");
    }
    // and the full snapshot still validates with a saturated ring
    assert!(snapshot::validate(&snap.to_json()).is_ok());
}

#[test]
fn stage_timing_off_costs_one_branch_but_batch_forward_still_records() {
    let obs = ObsConfig { stage_timers: false, trace: false, ..Default::default() };
    let mut s = pretrained_server(0, obs);
    drive(&mut s, 3, &clustered(11, 30, 0.0), false);

    let snap = s.obs_snapshot();
    assert!(!snap.flush_stages.enabled());
    assert_eq!(snap.flush_stages.total_ns(), 0, "disabled timers must not measure");
    assert_eq!(snap.flush_stages.sum_stage_ns(), 0);
    assert_eq!(snap.trace.recorded, 0, "disabled recorder must not record");
    // the pump-side wall-clock fallback keeps the latency histogram alive
    assert!(snap.metrics.batch_forward.count() > 0);
    assert!(snapshot::validate(&snap.to_json()).is_ok());
}

/// The paper's Tables 6/7 decomposition, live: a drift-triggered
/// fine-tune must attribute its wall-clock to cached-forward / backward /
/// update, and the rollups + trace must carry the tenant's story.
#[test]
fn finetune_attribution_reaches_metrics_rollups_and_trace() {
    let mut s = pretrained_server(0, ObsConfig::default());
    drive(&mut s, 0, &clustered(20, 60, 0.0), true); // control stays clean
    drive(&mut s, 1, &clustered(21, 300, 2.5), true); // hard drift
    s.quiesce();
    assert!(s.tenant_adaptations(1) >= 1, "drifted tenant must adapt");

    let m = &s.metrics;
    assert!(m.finetune_forward_ns > 0, "cached-forward time not attributed");
    assert!(m.finetune_backward_ns > 0, "backward time not attributed");
    assert!(m.finetune_update_ns > 0, "update time not attributed");
    // Skip2-LoRA's whole point: backward + update exist, and the forward
    // side rides the skip-cache rather than recomputing the backbone
    assert!(m.finetune.count() >= 1);

    let snap = s.obs_snapshot();
    let slot = snap
        .tenants
        .iter()
        .find(|t| t.tenant == 1)
        .expect("drifted tenant must be in the rollups");
    assert!(slot.finetunes >= 1);
    assert!(slot.finetune_ns > 0);
    assert!(slot.cache_hits + slot.cache_misses > 0, "cache activity must roll up");

    let count_of = |name: &str| -> u64 {
        snap.trace
            .counts
            .iter()
            .find(|(n, _)| *n == name)
            .map_or(0, |&(_, c)| c)
    };
    assert!(count_of("finetune_start") >= 1);
    assert!(count_of("finetune_end") >= 1);
    assert!(count_of("flush_start") >= 1);
    assert_eq!(count_of("flush_start"), count_of("flush_end"));
    assert!(snapshot::validate(&snap.to_json()).is_ok());
}
