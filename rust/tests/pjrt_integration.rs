//! PJRT integration tests: the AOT artifacts (jax/pallas -> HLO text)
//! executed from rust must reproduce the native engine's numerics and
//! support the full cached fine-tuning loop.
//!
//! Skipped (with a message) when `artifacts/` hasn't been built — run
//! `make artifacts` first. The whole suite is compiled only with
//! `--features pjrt` (the default build has no XLA toolchain).

#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use skip2lora::engine::pjrt::{one_hot, PjrtSkip2};
use skip2lora::experiments::{accuracy, DatasetId, ExpConfig};
use skip2lora::method::Method;
use skip2lora::model::mlp::AdapterTopology;
use skip2lora::model::AdapterSet;
use skip2lora::tensor::Mat;
use skip2lora::train::FineTuner;
use skip2lora::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn quick_cfg() -> ExpConfig {
    ExpConfig { trials: 1, epoch_scale: 0.08, seed: 3, ..Default::default() }
}

#[test]
fn pjrt_predict_matches_native() {
    let Some(dir) = artifacts() else { return };
    let cfg = quick_cfg();
    let ds = DatasetId::Damage1;
    let bench = ds.benchmark(cfg.seed);
    let backbone = accuracy::pretrain_backbone(ds, &bench, &cfg, 0);
    let mut rng = Rng::new(1);
    let mut adapters = AdapterSet::new(&mut rng, &backbone.config, AdapterTopology::Skip);
    for ad in adapters.adapters.iter_mut() {
        for v in ad.wb.data.iter_mut() {
            *v = 0.02 * rng.normal();
        }
    }
    let mut pjrt =
        PjrtSkip2::new(&dir, "fan", &backbone, &adapters.adapters).expect("open pjrt");
    let native = FineTuner::new(backbone, adapters, Method::SkipLora, cfg.backend, 20);

    let nfe = bench.test.n_features();
    let xb = Mat::from_vec(20, nfe, bench.test.x.data[..20 * nfe].to_vec());
    let want = native.predict_alloc(&xb);
    let got = pjrt.predict_batch(&xb.data).expect("pjrt predict");
    let max = want
        .data
        .iter()
        .zip(&got)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 2e-3, "max |Δ| = {max}");
}

#[test]
fn pjrt_finetune_loop_learns() {
    let Some(dir) = artifacts() else { return };
    let cfg = quick_cfg();
    let ds = DatasetId::Damage1;
    let bench = ds.benchmark(cfg.seed);
    let backbone = accuracy::pretrain_backbone(ds, &bench, &cfg, 0);
    let mut rng = Rng::new(2);
    let adapters = AdapterSet::new(&mut rng, &backbone.config, AdapterTopology::Skip);
    let mut pjrt =
        PjrtSkip2::new(&dir, "fan", &backbone, &adapters.adapters).expect("open pjrt");

    let acc_before = pjrt.accuracy(&bench.test).expect("acc");
    let (_loss, stats, _t) = pjrt.finetune(&bench.finetune, 8, 0.02, 5).expect("finetune");
    let acc_after = pjrt.accuracy(&bench.test).expect("acc");
    assert!(
        acc_after > acc_before + 0.1,
        "PJRT fine-tune must learn: {acc_before:.3} -> {acc_after:.3}"
    );
    assert!(stats.hits > 0, "cache unused");
    assert!(stats.misses <= bench.finetune.len() as u64);
}

#[test]
fn pjrt_step_matches_native_step() {
    let Some(dir) = artifacts() else { return };
    let cfg = quick_cfg();
    let t = skip2lora::experiments::pjrt_check::verify(&dir, &cfg).expect("verify");
    let rendered = t.render();
    println!("{rendered}");
    assert!(!rendered.contains("FAIL"), "cross-check failures:\n{rendered}");
}

#[test]
fn pjrt_har_artifacts_load_and_run() {
    let Some(dir) = artifacts() else { return };
    let cfg = quick_cfg();
    let ds = DatasetId::Har;
    let bench = ds.benchmark(cfg.seed);
    let backbone = accuracy::pretrain_backbone(ds, &bench, &cfg, 0);
    let mut rng = Rng::new(3);
    let adapters = AdapterSet::new(&mut rng, &backbone.config, AdapterTopology::Skip);
    let mut pjrt =
        PjrtSkip2::new(&dir, "har", &backbone, &adapters.adapters).expect("open har");
    // one populate + one step, shape sanity
    let b = pjrt.batch;
    let x: Vec<f32> = bench.finetune.x.data[..b * 561].to_vec();
    let (x2, x3, c3) = pjrt.cache_populate(&x).expect("populate");
    assert_eq!(x2.len(), b * 96);
    assert_eq!(x3.len(), b * 96);
    assert_eq!(c3.len(), b * 6);
    let y = one_hot(&bench.finetune.labels[..b], 6);
    let loss = pjrt.step(&x, &x2, &x3, &c3, &y, 0.02).expect("step");
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn pjrt_rejects_wrong_model_dims() {
    let Some(dir) = artifacts() else { return };
    let mut rng = Rng::new(4);
    let cfg = skip2lora::model::MlpConfig { dims: vec![10, 8, 8, 3], rank: 4, batch_norm: true };
    let wrong = skip2lora::model::Mlp::new(&mut rng, cfg.clone());
    let adapters = AdapterSet::new(&mut rng, &cfg, AdapterTopology::Skip);
    assert!(PjrtSkip2::new(&dir, "fan", &wrong, &adapters.adapters).is_err());
}
