//! Kernel-equivalence property tests: the packed/tiled kernel family vs
//! the naive oracles over randomized and degenerate shapes, plus the
//! serving tentpole invariant — the tenant-grouped fan-out is
//! BIT-IDENTICAL to the per-row reference under a seeded multi-tenant
//! flush.
//!
//! The strong form of the contract: every packed/tiled kernel preserves
//! the naive per-element accumulation order (ascending k, one product at
//! a time), so equality below is `assert_eq!` on the raw f32 bits, not a
//! tolerance — which is exactly what lets `MicroBatcher::flush` regroup
//! rows by tenant without changing a single served logit.

use std::sync::Arc;

use skip2lora::model::{Mlp, MlpConfig};
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::serve::batcher::{BatchRequest, BatchResponse, FrozenBackbone, MicroBatcher};
use skip2lora::serve::registry::AdapterRegistry;
use skip2lora::tensor::ops::{self, Backend, PackedB, NR};
use skip2lora::tensor::Mat;
use skip2lora::testkit::prop::{check, gen, PropConfig};
use skip2lora::util::rng::Rng;

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Shapes that stress every tile boundary: empty dims, single elements,
/// exact multiples of MR/NR, off-by-one around them, and the
/// capacity-padded serve shapes (rows ≥ the real batch).
fn adversarial_dims(rng: &mut Rng) -> (usize, usize, usize) {
    let pick = |rng: &mut Rng| -> usize {
        match gen::usize_in(rng, 0, 6) {
            0 => 0,
            1 => 1,
            2 => gen::usize_in(rng, 2, 5),       // below one tile
            3 => gen::usize_in(rng, 7, 10),      // around NR
            4 => 8 * gen::usize_in(rng, 1, 4),   // exact tile multiples
            _ => gen::usize_in(rng, 11, 40),     // past one tile, ragged
        }
    };
    (pick(rng), pick(rng), pick(rng))
}

#[test]
fn packed_matmul_matches_naive_bitwise_over_degenerate_shapes() {
    check("packed == naive (bits)", PropConfig { cases: 200, ..Default::default() }, |rng| {
        let (r, k, c) = adversarial_dims(rng);
        let a = gen::mat(rng, r, k);
        let b = gen::mat(rng, k, c);
        let mut want = Mat::zeros(r, c);
        ops::matmul_naive(&a, &b, &mut want);
        let mut pb = PackedB::new();
        pb.pack(&b);
        let mut got = Mat::zeros(r, c);
        ops::matmul_packed_into(&a, &pb, &mut got);
        if bits(&want.data) != bits(&got.data) {
            return Err(format!("packed != naive at {r}x{k}x{c}"));
        }
        // dispatch may legitimately route tiny shapes to blocked — that
        // path only needs tolerance-level agreement
        let mut routed = Mat::zeros(r, c);
        ops::matmul(Backend::Packed, &a, &b, &mut routed);
        for (w, g) in want.data.iter().zip(&routed.data) {
            if (w - g).abs() > 1e-4 * (1.0 + w.abs()) {
                return Err(format!("dispatch drifted at {r}x{k}x{c}: {w} vs {g}"));
            }
        }
        Ok(())
    });
}

#[test]
fn packed_a_bt_and_tiled_at_b_match_naive_bitwise() {
    check("aᵀb / abᵀ tiled == naive", PropConfig { cases: 150, ..Default::default() }, |rng| {
        let (bsz, n, m) = adversarial_dims(rng);
        // Aᵀ·B: mix dense and post-ReLU-sparse LHS (the probe's domain)
        let a = if gen::usize_in(rng, 0, 2) == 0 {
            gen::mat(rng, bsz, n)
        } else {
            gen::sparse_mat(rng, bsz, n, 0.5)
        };
        let b = gen::mat(rng, bsz, m);
        let mut want = Mat::zeros(n, m);
        ops::matmul_at_b_naive(&a, &b, &mut want);
        let mut tiled = Mat::zeros(n, m);
        ops::matmul_at_b_tiled(&a, &b, &mut tiled);
        if bits(&want.data) != bits(&tiled.data) {
            return Err(format!("at_b tiled != naive at {bsz}x{n}x{m}"));
        }
        // the skip-zero form changes only the order of SKIPPED zero
        // terms — tolerance, since ±0 products are elided
        let mut sparse = Mat::zeros(n, m);
        ops::matmul_at_b_sparse(&a, &b, &mut sparse);
        for (w, g) in want.data.iter().zip(&sparse.data) {
            if (w - g).abs() > 1e-4 * (1.0 + w.abs()) {
                return Err(format!("at_b sparse drifted: {w} vs {g}"));
            }
        }
        // A·Bᵀ through pack_transposed
        let x = gen::mat(rng, bsz, m);
        let w2 = gen::mat(rng, n, m);
        let mut want2 = Mat::zeros(bsz, n);
        ops::matmul_a_bt_naive(&x, &w2, &mut want2);
        let mut got2 = Mat::zeros(bsz, n);
        ops::matmul_a_bt_packed(&x, &w2, &mut got2);
        if bits(&want2.data) != bits(&got2.data) {
            return Err(format!("a_bt packed != naive at {bsz}x{m}x{n}"));
        }
        Ok(())
    });
}

#[test]
fn matmul_acc_matches_naive_bitwise() {
    check("matmul_acc == naive (bits)", PropConfig { cases: 100, ..Default::default() }, |rng| {
        let (r, k, c) = adversarial_dims(rng);
        let a = gen::mat(rng, r, k);
        let b = gen::mat(rng, k, c);
        let init = gen::mat(rng, r, c);
        let mut want = init.clone();
        ops::matmul_acc_naive(&a, &b, &mut want);
        for backend in [Backend::Blocked, Backend::Packed] {
            let mut got = init.clone();
            ops::matmul_acc(backend, &a, &b, &mut got);
            if bits(&want.data) != bits(&got.data) {
                return Err(format!("acc {backend:?} != naive at {r}x{k}x{c}"));
            }
        }
        Ok(())
    });
}

#[test]
fn capacity_padded_serve_shapes_are_row_stable() {
    // the serving contract behind partial flushes: a row's result must
    // not depend on how many OTHER rows ride in the (capacity-padded)
    // batch — checked at the kernel level across MR-block vs tail paths
    let cfg = PropConfig { cases: 80, ..Default::default() };
    check("row results are batch-size invariant", cfg, |rng| {
        let k = gen::usize_in(rng, 1, 40);
        let c = gen::usize_in(rng, NR, 40);
        let rows = gen::usize_in(rng, 1, 12);
        let b = gen::mat(rng, k, c);
        let mut pb = PackedB::new();
        pb.pack(&b);
        let a = gen::mat(rng, rows, k);
        let mut full = Mat::zeros(rows, c);
        ops::matmul_packed_into(&a, &pb, &mut full);
        for i in 0..rows {
            let solo = Mat::from_vec(1, k, a.row(i).to_vec());
            let mut out = Mat::zeros(1, c);
            ops::matmul_packed_into(&solo, &pb, &mut out);
            if bits(out.row(0)) != bits(full.row(i)) {
                return Err(format!("row {i}/{rows} depends on its batch context"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// the serving tentpole invariant
// ---------------------------------------------------------------------------

fn serve_cfg() -> MlpConfig {
    MlpConfig { dims: vec![12, 16, 16, 4], rank: 3, batch_norm: true }
}

fn publish_fleet(rng: &mut Rng, registry: &AdapterRegistry, tenants: u64) {
    let cfg = serve_cfg();
    for t in 0..tenants {
        let mut ads: Vec<LoraAdapter> = (0..3)
            .map(|k| LoraAdapter::new(rng, cfg.dims[k], 3, 4))
            .collect();
        for ad in ads.iter_mut() {
            for v in ad.wb.data.iter_mut() {
                *v = 0.15 * rng.normal();
            }
        }
        registry.publish(t, ads);
    }
}

fn flush_logits(batcher: &MicroBatcher, out: &[BatchResponse]) -> Vec<(u64, Vec<u32>)> {
    out.iter()
        .map(|r| (r.id, bits(batcher.logits_for(r).expect("rows of the latest flush"))))
        .collect()
}

#[test]
fn grouped_fanout_is_bit_identical_to_per_row_reference_under_seeded_flushes() {
    // the acceptance invariant: seeded multi-tenant traffic (mixed group
    // sizes, unknown tenants, partial batches) served by the grouped
    // zero-alloc flush is byte-identical to the per-row reference AND to
    // one-request-at-a-time serving
    let mut rng = Rng::new(0xF1E1D);
    let backbone = Arc::new(Mlp::new(&mut rng, serve_cfg()));
    let registry = Arc::new(AdapterRegistry::new());
    publish_fleet(&mut rng, &registry, 6);

    let capacity = 16usize;
    let grouped_fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Packed, capacity);
    let mut grouped = MicroBatcher::new(grouped_fb, Arc::clone(&registry));
    let reference_fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Packed, capacity);
    let mut reference = MicroBatcher::new(reference_fb, Arc::clone(&registry));
    let solo_fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Packed, capacity);
    let mut solo = MicroBatcher::new(solo_fb, Arc::clone(&registry));

    for round in 0..12u64 {
        // seeded traffic: batch sizes 1..=capacity, tenants 0..8 (6 and 7
        // have nothing published → bare backbone rows inside the batch)
        let b = 1 + (rng.next_u64() % capacity as u64) as usize;
        let reqs: Vec<BatchRequest> = (0..b)
            .map(|i| BatchRequest {
                tenant: rng.next_u64() % 8,
                id: round * 100 + i as u64,
                x: (0..12).map(|_| rng.normal()).collect(),
                label: (i % 3 == 0).then_some((i % 4).min(3)),
            })
            .collect();

        let mut out_g = Vec::new();
        for r in reqs.iter().cloned() {
            grouped.submit(r);
        }
        assert_eq!(grouped.flush(&mut out_g), b);
        let logits_g = flush_logits(&grouped, &out_g);

        let mut out_r = Vec::new();
        for r in reqs.iter().cloned() {
            reference.submit(r);
        }
        assert_eq!(reference.flush_reference(&mut out_r), b);
        let logits_r = flush_logits(&reference, &out_r);
        assert_eq!(logits_g, logits_r, "round {round}: grouped != per-row reference");
        for (g, r) in out_g.iter().zip(&out_r) {
            assert_eq!(g.prediction, r.prediction);
            assert_eq!(g.adapter_version, r.adapter_version);
            assert_eq!(g.x, r.x, "x echo policy must match");
        }

        // and against one-at-a-time serving (regrouping must be invisible)
        for (req, (id, want)) in reqs.iter().zip(&logits_g) {
            let mut out_s = Vec::new();
            solo.submit(req.clone());
            assert_eq!(solo.flush(&mut out_s), 1);
            assert_eq!(req.id, *id);
            assert_eq!(
                &bits(solo.logits_for(&out_s[0]).expect("just flushed")),
                want,
                "round {round}: solo serving of request {id} drifted"
            );
        }
    }
}

#[test]
fn grouped_fanout_handles_degenerate_adapter_shapes() {
    // rank-0 adapters and 0-row groups must flow through the grouped
    // GEMMs without panicking (k=0 / 0-row mats are legal kernel inputs)
    let mut rng = Rng::new(77);
    let cfg = serve_cfg();
    let backbone = Arc::new(Mlp::new(&mut rng, cfg.clone()));
    let registry = Arc::new(AdapterRegistry::new());
    let ads: Vec<LoraAdapter> = (0..3)
        .map(|k| LoraAdapter::new(&mut rng, cfg.dims[k], 0, 4)) // rank 0
        .collect();
    registry.publish(1, ads);
    let fb = FrozenBackbone::new(Arc::clone(&backbone), Backend::Packed, 4);
    let mut batcher = MicroBatcher::new(fb, registry);
    let x: Vec<f32> = (0..12).map(|_| rng.normal()).collect();
    batcher.submit(BatchRequest { tenant: 1, id: 0, x: x.clone(), label: None });
    batcher.submit(BatchRequest { tenant: 2, id: 1, x, label: None });
    let mut out = Vec::new();
    assert_eq!(batcher.flush(&mut out), 2);
    // rank-0 adapters are an exact no-op: both rows saw the bare backbone
    assert_eq!(
        bits(batcher.last_logits().row(out[0].row)),
        bits(batcher.last_logits().row(out[1].row)),
    );
    assert!(out[0].adapter_version > 0);
    assert_eq!(out[1].adapter_version, 0);
}
