//! Chaos acceptance for the fault-tolerant fleet plane (DESIGN.md §15):
//! a 3-node loopback fleet where every node sits behind a deterministic
//! [`FaultProxy`], driven through one seeded scenario that
//!
//! * kills a node mid-RPC (response cut mid-frame, reconnects refused) —
//!   the router retries, declares it dead past the budget, re-installs
//!   the latest checkpoint on the survivors, and fails the admission
//!   over to the rendezvous successor,
//! * stalls another node mid-frame — the call is bounded by
//!   `rpc_timeout`, the retry replays the recorded admission from the
//!   server's dedupe log (at-most-once), and the node recovers in-call,
//! * runs a stretch of seeded chaos (cuts/delays drawn per response
//!   ordinal) over the survivors,
//! * cuts a pump response so the suspect/probe/recover path runs on the
//!   deterministic tick clock,
//!
//! and asserts: no router call ever hangs, the books balance
//! (completions == admissions, the dead node's zombie admission is
//! provably parked and never double-counted), surviving tenants serve
//! BIT-IDENTICAL predictions to an unfaulted in-process oracle, and the
//! whole `fleet_health` section — states, counters, transition log —
//! replays bit-identically when the same seeded scenario runs again.

use std::time::{Duration, Instant};

use skip2lora::data::Dataset;
use skip2lora::fleet::{FleetRouter, HealthPolicy, NodeState, RebalanceConfig, RouterConfig};
use skip2lora::model::MlpConfig;
use skip2lora::net::{Admission, ClientConfig, ClientError, NodeClient, NodeServer};
use skip2lora::obs::snapshot::validate as validate_obs;
use skip2lora::serve::{FleetServer, Request, Response, ServeConfig};
use skip2lora::tensor::ops::Backend;
use skip2lora::tensor::Mat;
use skip2lora::testkit::{FaultPlan, FaultProxy, RespFault};
use skip2lora::train::trainer::pretrain;
use skip2lora::util::rng::Rng;

const N_TENANTS: u64 = 6;
/// feedback rounds per tenant — enough past `buffer_target` that every
/// drifted tenant fine-tunes and PUBLISHES before the chaos starts, so
/// checkpoint recovery has real trained state to re-install
const ROUNDS: usize = 36;
const PROBES: usize = 6;
const CHAOS_ROUNDS: usize = 8;
const SEED: u64 = 41;

/// Generous wall-clock hang detector. Scripted faults resolve in at most
/// a couple of `rpc_timeout`s; anything near this bound means a retry
/// loop stopped terminating.
const HANG: Duration = Duration::from_secs(30);

fn clustered(seed: u64, n: usize, shift: f32) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Mat::zeros(n, 8);
    let mut labels = Vec::new();
    for i in 0..n {
        let c = i % 3;
        for j in 0..8 {
            let base = if j % 3 == c { 2.0 } else { 0.0 };
            *x.at_mut(i, j) = base + shift + 0.3 * rng.normal();
        }
        labels.push(c);
    }
    Dataset {
        x,
        labels,
        n_classes: 3,
    }
}

fn serve_config() -> ServeConfig {
    ServeConfig {
        batch_capacity: 16,
        window: 20,
        accuracy_threshold: 0.7,
        buffer_target: 30,
        epochs: 20,
        lr: 0.05,
        train_batch: 15,
        // inline fine-tunes: the pump clock fully determines execution,
        // which is what makes the cross-run replay comparison exact
        workers: 0,
        ..Default::default()
    }
}

fn backbone() -> skip2lora::model::Mlp {
    let cfg = MlpConfig {
        dims: vec![8, 12, 12, 3],
        rank: 2,
        batch_norm: true,
    };
    pretrain(cfg, &clustered(0, 120, 0.0), 50, 0.05, 1, Backend::Blocked)
}

fn new_server(bb: &skip2lora::model::Mlp) -> FleetServer {
    FleetServer::new(bb.clone(), serve_config())
}

fn drifted(t: u64) -> bool {
    t % 3 != 0
}

fn tenant_stream(t: u64) -> Dataset {
    let shift = if drifted(t) { 2.5 } else { 0.0 };
    clustered(1000 + t, ROUNDS, shift)
}

fn chaos_router_config(ckpt: String) -> RouterConfig {
    RouterConfig {
        client: ClientConfig {
            connect_timeout: Duration::from_secs(2),
            // every request→response exchange is bounded by this; large
            // enough that no HEALTHY rpc (including an inline-fine-tune
            // pump) ever times out, so the health log stays scripted
            rpc_timeout: Duration::from_secs(2),
            // 5 attempts per node: the scripted kill exhausts them all,
            // while a seeded chaos cut recovers on the first retry
            max_retries: 4,
            backoff_ticks: 2,
            token: None,
            client_id: 1,
        },
        health: HealthPolicy {
            // the scripted kill dies via BUDGET exhaustion (5 failed
            // attempts < 6 strikes), exercising that death path; chaos
            // strikes reset on every recovered call
            dead_after_strikes: 6,
            backoff_ticks: 2,
        },
        rebalance: None,
        recovery_checkpoint: Some(ckpt),
    }
}

/// Everything a scenario run produces that must replay bit-identically.
#[derive(PartialEq, Debug)]
struct ScenarioOut {
    health_json: String,
    preds: Vec<usize>,
    admitted: u64,
    completed: u64,
}

/// One full seeded chaos scenario over a fresh 3-node fleet. Pure in
/// `seed`: every fault is either scripted at a response/connection
/// ordinal or drawn by `chaos_draw(seed, ordinal)`, and the driver is
/// single-threaded, so two runs see identical ordinal sequences.
fn scenario(seed: u64) -> ScenarioOut {
    let bb = backbone();
    let mut servers = Vec::new();
    let mut proxies = Vec::new();
    for _ in 0..3 {
        let ns = NodeServer::spawn(new_server(&bb), "127.0.0.1:0").unwrap();
        let px = FaultProxy::spawn(&ns.addr().to_string(), FaultPlan::transparent()).unwrap();
        servers.push(Some(ns));
        proxies.push(px);
    }
    let mut oracle = new_server(&bb);

    let ckpt = std::env::temp_dir().join(format!(
        "s2l_chaos_ckpt_{}_{seed}.bin",
        std::process::id()
    ));
    let mut router = FleetRouter::with_config(chaos_router_config(
        ckpt.to_string_lossy().into_owned(),
    ));
    for (i, px) in proxies.iter().enumerate() {
        router.add_node(&format!("node{i}"), px.addr()).unwrap();
    }
    assert_eq!(router.alive_count(), 3);

    let streams: Vec<Dataset> = (0..N_TENANTS).map(tenant_stream).collect();
    let mut admitted = 0u64;
    let mut completed = 0u64;
    let mut sends = 0usize;

    // ---- phase 1: healthy labelled traffic, oracle mirrored with the
    // identical per-tenant streams and pump cadence
    for round in 0..ROUNDS {
        for t in 0..N_TENANTS {
            let x = streams[t as usize].x.row(round).to_vec();
            let label = streams[t as usize].labels[round];
            match router.feedback(t, x.clone(), label as u32).unwrap() {
                Admission::Queued { .. } => admitted += 1,
                other => panic!("unexpected rejection: {other:?}"),
            }
            match oracle.handle(t, Request::Feedback(x, label)) {
                Response::Queued { .. } => {}
                other => panic!("oracle rejected: {other:?}"),
            }
            sends += 1;
            if sends % 16 == 0 {
                completed += router.pump_all().unwrap().len() as u64;
                oracle.pump();
            }
        }
    }
    completed += router.pump_drain_all().unwrap().len() as u64;
    oracle.pump_until_drained();

    // the fleet-wide recovery checkpoint: the oracle holds the identical
    // published state of EVERY tenant (that is what phase 2 proves), so
    // its checkpoint can re-home any dead node's tenants
    oracle.persist_to(&ckpt).unwrap();

    let probes = clustered(777, CHAOS_ROUNDS.max(PROBES), 1.0);

    // ---- scripted kill: victim = tenant 1's home. Cut its next
    // response mid-frame (AFTER the server admits — the ambiguous
    // outcome), then refuse every reconnect. HRW places tenant 1 on
    // node0 and re-homes it to node2 (deterministic hash, asserted).
    let victim = router.route(1).unwrap();
    assert_eq!(victim, 0, "rendezvous placement changed?");
    {
        let vp = &proxies[victim];
        vp.set_plan(
            FaultPlan::transparent()
                .fault_resp(vp.resps_seen(), RespFault::Cut { keep: 2 })
                .refuse_conns_from(vp.conns_seen()),
        );
    }
    let t0 = Instant::now();
    match router.predict(1, probes.x.row(0).to_vec()).unwrap() {
        Admission::Queued { .. } => admitted += 1,
        other => panic!("failover admission rejected: {other:?}"),
    }
    assert!(t0.elapsed() < HANG, "kill path did not stay bounded");
    assert_eq!(router.node_state(victim), NodeState::Dead);
    assert_eq!(router.alive_count(), 2);
    assert_eq!(router.route(1), Some(2), "tenant 1 re-homed to successor");
    {
        let c = &router.health().counters;
        assert_eq!(c.deaths, 1);
        assert_eq!(c.failovers, 1);
        assert!(c.rpc_retries >= 4, "budget not spent: {c:?}");
        assert!(c.reconnects >= 4);
        assert!(
            c.recovered_tenants >= 1,
            "checkpoint recovery installed nothing: {c:?}"
        );
    }

    // ---- scripted stall: node1 (home of tenants 2/4/5) wedges
    // mid-frame on its next response. The call is bounded by
    // rpc_timeout; the retry reconnects and REPLAYS the recorded
    // admission (same req_id), so the queue holds exactly one copy.
    let stall = 1usize;
    {
        let sp = &proxies[stall];
        sp.set_plan(
            FaultPlan::transparent().fault_resp(sp.resps_seen(), RespFault::Stall { keep: 3 }),
        );
    }
    let t1 = Instant::now();
    match router.predict(2, probes.x.row(0).to_vec()).unwrap() {
        Admission::Queued { .. } => admitted += 1,
        other => panic!("stalled admission rejected: {other:?}"),
    }
    assert!(
        t1.elapsed() < Duration::from_secs(12),
        "stall was not bounded by rpc_timeout"
    );
    assert_eq!(
        router.node_state(stall),
        NodeState::Alive,
        "stalled node recovers in-call"
    );
    // at-most-once: the stalled request was queued server-side AND its
    // retry was deduped — one kill failover + one stalled predict = two
    // queue entries across the fleet, not three
    assert_eq!(router.queue_depth_total().unwrap(), 2);
    completed += router.pump_drain_all().unwrap().len() as u64;

    // ---- seeded chaos stretch over the survivors: label-free traffic
    // (predicts mutate nothing, so ANY recovery path stays bit-exact),
    // cuts ride the dedupe log, delays ride the timeout slack
    for round in 0..CHAOS_ROUNDS {
        for idx in [1usize, 2] {
            proxies[idx].set_plan(FaultPlan::from_seed(seed ^ idx as u64));
        }
        for t in 0..N_TENANTS {
            let t2 = Instant::now();
            match router.predict(t, probes.x.row(round).to_vec()).unwrap() {
                Admission::Queued { .. } => admitted += 1,
                other => panic!("chaos probe rejected: {other:?}"),
            }
            assert!(t2.elapsed() < HANG, "chaos call hung");
        }
        // drain through quiet proxies so a chaos draw can never land on
        // a pump response (whose loss would drop completions)
        for idx in [1usize, 2] {
            proxies[idx].set_plan(FaultPlan::transparent());
        }
        completed += router.pump_drain_all().unwrap().len() as u64;
    }
    assert_eq!(router.node_state(1), NodeState::Alive);
    assert_eq!(router.node_state(2), NodeState::Alive);
    assert_eq!(router.health().counters.deaths, 1, "chaos killed a survivor");

    // ---- pump-path fault: cut node2's next (empty) pump response; the
    // pump strikes it to Suspect, and the tick-scheduled probe recovers
    // it two pumps later — the backoff is pump ticks, not wall clock
    {
        let pp = &proxies[2];
        pp.set_plan(
            FaultPlan::transparent().fault_resp(pp.resps_seen(), RespFault::Cut { keep: 1 }),
        );
    }
    completed += router.pump_all().unwrap().len() as u64;
    assert_eq!(router.node_state(2), NodeState::Suspect);
    proxies[2].set_plan(FaultPlan::transparent());
    let probes_before = router.health().counters.probes;
    completed += router.pump_all().unwrap().len() as u64; // backoff tick 1: not due
    assert_eq!(router.node_state(2), NodeState::Suspect);
    completed += router.pump_all().unwrap().len() as u64; // backoff tick 2: probe fires
    assert_eq!(router.node_state(2), NodeState::Alive);
    assert_eq!(router.health().counters.probes, probes_before + 1);
    assert_eq!(router.health().counters.probe_failures, 0);

    // ---- phase 2: serving continues through the two survivors —
    // predictions for EVERY tenant (including the dead node's, now
    // served from the recovered checkpoint) bit-identical to the oracle
    let mut preds = Vec::new();
    for t in 0..N_TENANTS {
        for p in 0..PROBES {
            let x = probes.x.row(p).to_vec();
            match router.predict(t, x.clone()).unwrap() {
                Admission::Queued { .. } => admitted += 1,
                other => panic!("probe rejected: {other:?}"),
            }
            let done = router.pump_drain_all().unwrap();
            assert_eq!(done.len(), 1);
            completed += 1;
            preds.push(done[0].prediction);

            match oracle.handle(t, Request::Predict(x)) {
                Response::Queued { .. } => {}
                other => panic!("oracle probe rejected: {other:?}"),
            }
            let oracle_done = oracle.pump_until_drained();
            assert_eq!(oracle_done.len(), 1);
            assert_eq!(
                done[0].prediction, oracle_done[0].prediction,
                "tenant {t} probe {p}: fleet diverged from the unfaulted oracle"
            );
            let serving = router.route(t).unwrap();
            assert!(router.node_state(serving) == NodeState::Alive);
        }
    }

    // ---- books: every admission the router acknowledged completed
    // exactly once, across retries, failover, and chaos
    assert_eq!(
        completed, admitted,
        "completions must equal admissions (zero lost, zero duplicated)"
    );

    // the merged fleet document still validates and carries the
    // fleet_health section
    let merged = router.fleet_obs().unwrap();
    validate_obs(&merged).expect("fleet-merged document must validate under chaos");
    assert!(merged.get("fleet_health").is_some());

    let names: Vec<String> = (0..3).map(|i| format!("node{i}")).collect();
    let health_json = router
        .health()
        .to_json(router.current_tick(), &names)
        .to_string();

    for px in proxies {
        px.shutdown();
    }
    // the dead node's server still holds EXACTLY the one zombie
    // admission whose response was cut after it was queued — proof the
    // ambiguous outcome was real and the failover did not double-admit
    let dead = servers[victim].take().unwrap().shutdown();
    assert_eq!(dead.queued(), 1, "expected exactly the one zombie admission");
    for s in servers.into_iter().flatten() {
        s.shutdown();
    }
    oracle.shutdown();
    let _ = std::fs::remove_file(&ckpt);

    ScenarioOut {
        health_json,
        preds,
        admitted,
        completed,
    }
}

#[test]
fn seeded_kill_and_stall_chaos_is_survivable_and_replays_bit_identically() {
    let a = scenario(SEED);
    // the scenario's own asserts carry the survivability criteria; the
    // second run proves the SAME seed reproduces the identical health
    // transition log, counters, predictions, and books
    let b = scenario(SEED);
    assert_eq!(
        a.health_json, b.health_json,
        "fleet_health must replay bit-identically from the seed"
    );
    assert_eq!(a, b, "scenario outcome must be a pure function of the seed");
}

#[test]
fn injected_garbage_is_a_protocol_error_not_a_retry_loop() {
    let bb = backbone();
    let ns = NodeServer::spawn(new_server(&bb), "127.0.0.1:0").unwrap();
    // response ordinal 0 is the HelloOk; garbage lands on the first verb
    let px = FaultProxy::spawn(
        &ns.addr().to_string(),
        FaultPlan::transparent().fault_resp(1, RespFault::Garbage { len: 16 }),
    )
    .unwrap();
    let mut c = NodeClient::connect(px.addr()).unwrap();
    match c.queue_depth() {
        Err(e @ ClientError::Protocol(_)) => {
            assert!(
                !e.is_retryable(),
                "a peer speaking garbage is not a transient fault"
            );
        }
        other => panic!("expected a protocol violation, got {other:?}"),
    }
    drop(c);
    px.shutdown();
    ns.shutdown();
}

#[test]
fn background_rebalance_fires_on_cadence_with_hysteresis_and_cooldown() {
    let bb = backbone();
    let mut nodes = Vec::new();
    for _ in 0..2 {
        nodes.push(NodeServer::spawn(new_server(&bb), "127.0.0.1:0").unwrap());
    }
    let mut router = FleetRouter::new();
    for (i, n) in nodes.iter().enumerate() {
        router
            .add_node(&format!("node{i}"), &n.addr().to_string())
            .unwrap();
    }

    // all-drifted tenants so every one publishes trained adapters (the
    // skew probe counts registry tenants)
    let tenants: Vec<u64> = (1..9).filter(|&t| drifted(t)).collect();
    let mut sends = 0usize;
    for round in 0..ROUNDS {
        for &t in &tenants {
            let data = tenant_stream(t);
            let x = data.x.row(round).to_vec();
            match router.feedback(t, x, data.labels[round] as u32).unwrap() {
                Admission::Queued { .. } => {}
                other => panic!("{other:?}"),
            }
            sends += 1;
            if sends % 16 == 0 {
                router.pump_all().unwrap();
            }
        }
    }
    router.pump_drain_all().unwrap();

    // balanced fleet: the cadence runs but the high watermark holds
    router.set_rebalance(Some(RebalanceConfig {
        every_ticks: 1,
        high_watermark: 1.2,
        low_watermark: 1.0,
        cooldown_ticks: 1000,
    }));
    router.pump_all().unwrap();
    assert_eq!(
        router.health().counters.rebalances,
        0,
        "no migration below the high watermark"
    );

    // force a hot node: migrate everything node1 owns onto node0
    let on_node1: Vec<u64> = tenants
        .iter()
        .copied()
        .filter(|&t| router.route(t) == Some(1))
        .collect();
    assert!(!on_node1.is_empty(), "rendezvous starved node1?");
    assert!(on_node1.len() < tenants.len(), "rendezvous starved node0?");
    for &t in &on_node1 {
        router.migrate_tenant(t, 0).unwrap();
    }
    assert!(
        router.skew().unwrap().max_over_mean > 1.2,
        "forced imbalance below the watermark"
    );

    // next pump tick: exactly one rebalance step fires...
    router.pump_all().unwrap();
    assert_eq!(router.health().counters.rebalances, 1);
    let moved: Vec<u64> = tenants
        .iter()
        .copied()
        .filter(|&t| router.route(t) == Some(1))
        .collect();
    assert_eq!(moved.len(), 1, "one tenant moved off the hot node");

    // ...and the cooldown suppresses the next, even though skew remains
    router.pump_all().unwrap();
    router.pump_all().unwrap();
    assert_eq!(
        router.health().counters.rebalances,
        1,
        "cooldown must suppress back-to-back migrations"
    );

    for n in nodes {
        n.shutdown();
    }
}
