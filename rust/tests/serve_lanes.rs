//! Lane-parity proof suite (DESIGN.md §13): serving through N
//! tenant-hash-routed lanes must be **byte-identical** to single-lane
//! serving for every request — under the production pump schedule AND
//! under forced adversarial schedules (out-of-order force-flushes,
//! seeded pump/flush coin flips, deadline-starved partial batches).
//!
//! The harness is `testkit::lanes`: one seeded stream replayed through
//! lane sets of width 1/2/4/8; logits captured as `f32::to_bits` per
//! `(tenant, id)` immediately after every flush; books
//! (`completed + queued == admitted`) audited per lane at every step.
//!
//! The final section is a `testkit::stress` scenario: concurrent
//! publishers churn adapter versions while lane sets pump on observer
//! threads — per-tenant `adapter_version` monotonicity must survive lane
//! routing (a lane must never serve an older snapshot after a newer one).

use std::sync::Arc;

use skip2lora::model::{Mlp, MlpConfig};
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::obs::snapshot;
use skip2lora::serve::batcher::{BatchRequest, FrozenBackbone, MicroBatcher};
use skip2lora::serve::lanes::{lane_of, LaneSet};
use skip2lora::serve::registry::AdapterRegistry;
use skip2lora::serve::server::RejectReason;
use skip2lora::serve::{FleetServer, Request, Response, ServeConfig};
use skip2lora::tensor::ops::Backend;
use skip2lora::testkit::lanes::{
    assert_parity, publish_adapters, replay, seeded_stream, ReplayConfig, Schedule,
};
use skip2lora::testkit::stress::{self, StressConfig};
use skip2lora::util::rng::Rng;

const DIMS: [usize; 4] = [10, 14, 14, 4];

/// Backbone + registry with a deliberately mixed tenant population:
/// rank-4 personalized tenants (0,1,2,5), rank-0 degenerate adapters
/// (3,8), and unpublished tenants (7,11) served the bare backbone.
fn fixture() -> (Arc<Mlp>, Arc<AdapterRegistry>) {
    let mut rng = Rng::new(0x1A7E5);
    let backbone = Arc::new(Mlp::new(
        &mut rng,
        MlpConfig { dims: DIMS.to_vec(), rank: 4, batch_norm: true },
    ));
    let registry = Arc::new(AdapterRegistry::new());
    publish_adapters(
        &registry,
        &mut rng,
        &DIMS,
        &[(0, 4), (1, 4), (2, 4), (5, 4), (3, 0), (8, 0)],
    );
    (backbone, registry)
}

const TENANTS: [u64; 8] = [0, 1, 2, 3, 5, 7, 8, 11];

// ---------------------------------------------------------------------
// tentpole: N-lane == 1-lane, bit for bit
// ---------------------------------------------------------------------

#[test]
fn n_lane_serving_is_bit_identical_to_single_lane() {
    let (backbone, registry) = fixture();
    for seed in [3u64, 0xFEED, 91] {
        let stream = seeded_stream(seed, 96, DIMS[0], &TENANTS);
        let baseline = replay(
            &backbone,
            &registry,
            &stream,
            &ReplayConfig { n_lanes: 1, ..Default::default() },
        );
        for n_lanes in [2usize, 4, 8] {
            let wide = replay(
                &backbone,
                &registry,
                &stream,
                &ReplayConfig { n_lanes, ..Default::default() },
            );
            assert_parity(&baseline, &wide);
        }
    }
}

#[test]
fn adversarial_schedules_cannot_break_parity() {
    let (backbone, registry) = fixture();
    let stream = seeded_stream(0xD15C0, 80, DIMS[0], &TENANTS);
    let baseline = replay(
        &backbone,
        &registry,
        &stream,
        &ReplayConfig { n_lanes: 1, ..Default::default() },
    );
    // force-flush lanes in hostile orders: reverse, one-lane-starves,
    // and a pair of seeded coin-flip schedules
    let schedules = [
        Schedule::LaneOrder(vec![3, 2, 1, 0]),
        Schedule::LaneOrder(vec![0, 0, 0, 1, 2, 3]),
        Schedule::Seeded(0xC01),
        Schedule::Seeded(0xC02),
    ];
    for schedule in schedules {
        for n_lanes in [2usize, 4] {
            let adversarial = replay(
                &backbone,
                &registry,
                &stream,
                &ReplayConfig {
                    n_lanes,
                    submit_chunk: 2,
                    schedule: schedule.clone(),
                    ..Default::default()
                },
            );
            assert_parity(&baseline, &adversarial);
        }
    }
}

#[test]
fn deadline_starved_partial_batches_keep_parity() {
    let (backbone, registry) = fixture();
    // capacity far above the stream rate: only the deadline can flush,
    // so every batch is partial and lane fill levels diverge wildly
    let stream = seeded_stream(0xAB, 30, DIMS[0], &TENANTS);
    let cfg = |n_lanes| ReplayConfig {
        n_lanes,
        capacity: 64,
        deadline_pumps: 3,
        submit_chunk: 1,
        ..Default::default()
    };
    let baseline = replay(&backbone, &registry, &stream, &cfg(1));
    for n_lanes in [2usize, 4, 8] {
        assert_parity(&baseline, &replay(&backbone, &registry, &stream, &cfg(n_lanes)));
    }
}

#[test]
fn backend_choice_is_orthogonal_to_lane_parity() {
    let (backbone, registry) = fixture();
    let stream = seeded_stream(0x5EED, 48, DIMS[0], &TENANTS);
    for backend in [Backend::Scalar, Backend::Blocked, Backend::Packed] {
        let one = replay(
            &backbone,
            &registry,
            &stream,
            &ReplayConfig { n_lanes: 1, backend, ..Default::default() },
        );
        let four = replay(
            &backbone,
            &registry,
            &stream,
            &ReplayConfig { n_lanes: 4, backend, ..Default::default() },
        );
        assert_parity(&one, &four);
    }
}

// ---------------------------------------------------------------------
// degenerate tenants: rank-0 adapters and unpublished tenants
// ---------------------------------------------------------------------

#[test]
fn rank_zero_adapter_serves_exactly_the_bare_backbone() {
    let (backbone, registry) = fixture();
    // tenant 3 has a published rank-0 adapter; tenant 7 is unpublished.
    // Both must produce byte-identical logits for the same input, on
    // every lane width.
    let mut rng = Rng::new(9);
    let xs: Vec<Vec<f32>> = (0..6)
        .map(|_| (0..DIMS[0]).map(|_| rng.uniform(-1.0, 1.0)).collect())
        .collect();
    for n_lanes in [1usize, 4] {
        let mut lanes = LaneSet::new(n_lanes, 16, false, |_| {
            let frozen = FrozenBackbone::new(Arc::clone(&backbone), Backend::Blocked, 4);
            MicroBatcher::with_limits(frozen, Arc::clone(&registry), 1, 1024)
        });
        let mut bits_rank0 = Vec::new();
        let mut bits_unpub = Vec::new();
        for (tenant, bits) in [(3u64, &mut bits_rank0), (7u64, &mut bits_unpub)] {
            for (i, x) in xs.iter().enumerate() {
                let mut out = Vec::new();
                lanes
                    .try_submit(BatchRequest {
                        tenant,
                        id: i as u64 + 1,
                        x: x.clone(),
                        label: None,
                    })
                    .unwrap();
                lanes.flush_lane(lanes.lane_of(tenant), &mut out);
                assert_eq!(out.len(), 1);
                let row = lanes.logits_for(&out[0]).expect("fresh logits");
                bits.push(row.iter().map(|v| v.to_bits()).collect::<Vec<u32>>());
            }
        }
        assert_eq!(
            bits_rank0, bits_unpub,
            "rank-0 adapter must serve the bare backbone ({n_lanes} lanes)"
        );
    }
}

// ---------------------------------------------------------------------
// FleetServer integration: lanes behind the full admission pipeline
// ---------------------------------------------------------------------

fn serve_cfg(lanes: usize) -> ServeConfig {
    let mut cfg = ServeConfig {
        batch_capacity: 8,
        workers: 0,
        lanes,
        ..Default::default()
    };
    cfg.obs.stage_timers = true;
    cfg
}

#[test]
fn fleet_server_predictions_match_across_lane_widths() {
    let (backbone, _) = fixture();
    let mut rng = Rng::new(0xF00D);
    let reqs: Vec<(u64, Vec<f32>)> = (0..60)
        .map(|i| {
            let tenant = TENANTS[rng.below(TENANTS.len())];
            let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let _ = i;
            (tenant, x)
        })
        .collect();
    let run = |lanes: usize| {
        let mut s = FleetServer::new((*backbone).clone(), serve_cfg(lanes));
        for (tenant, x) in &reqs {
            match s.handle(*tenant, Request::Predict(x.clone())) {
                Response::Queued { .. } => {}
                other => panic!("admission failed: {other:?}"),
            }
        }
        let mut done: Vec<_> = s
            .pump_until_drained()
            .into_iter()
            .map(|c| (c.tenant, c.ticket, c.prediction))
            .collect();
        done.sort();
        let stats = s.stats();
        assert_eq!(stats.queued, 0);
        assert_eq!(stats.rows, reqs.len() as u64);
        done
    };
    let baseline = run(1);
    for lanes in [2usize, 4] {
        assert_eq!(baseline, run(lanes), "{lanes}-lane fleet serving diverged");
    }
}

#[test]
fn multi_lane_obs_snapshot_self_validates() {
    let (backbone, _) = fixture();
    let mut s = FleetServer::new((*backbone).clone(), serve_cfg(4));
    let mut rng = Rng::new(5);
    for i in 0..40u64 {
        let tenant = TENANTS[rng.below(TENANTS.len())];
        let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.uniform(-1.0, 1.0)).collect();
        match s.handle(tenant, Request::Predict(x)) {
            Response::Queued { .. } => {}
            other => panic!("req {i}: {other:?}"),
        }
    }
    let _ = s.pump_until_drained();
    let snap = s.obs_snapshot();
    assert_eq!(snap.lanes.len(), 4, "a 4-lane server must expose 4 lane rows");
    let json = snap.to_json();
    snapshot::validate(&json).expect("multi-lane snapshot must self-validate");
    // per-lane books close and roll up to the fleet counters
    let (mut admitted, mut completed, mut rows) = (0u64, 0u64, 0u64);
    for l in &snap.lanes {
        assert_eq!(l.completed + l.queued as u64, l.admitted, "lane {} books", l.lane);
        admitted += l.admitted;
        completed += l.completed;
        rows += l.rows;
    }
    assert_eq!(admitted, 40);
    assert_eq!(completed, 40);
    assert_eq!(rows, snap.metrics.batched_rows);
    // single-lane server emits the legacy document: no lanes key at all
    let s1 = FleetServer::new((*backbone).clone(), serve_cfg(1));
    let legacy = s1.obs_snapshot();
    assert!(legacy.lanes.is_empty());
    assert!(!legacy.to_json().to_string().contains("\"lanes\""));
    snapshot::validate(&legacy.to_json()).expect("legacy snapshot still validates");
}

// ---------------------------------------------------------------------
// drain × lanes: closing admissions while several lanes sit flush-due
// ---------------------------------------------------------------------

/// The graceful drain (§12) meeting the multi-lane flush path (§13):
/// with ≥2 lanes holding full, flush-due batches at drain time, every
/// queued request on every lane must come back in the drain report, every
/// lane's books must close, admissions must reject with the typed
/// `Draining` reason, and `resume_admissions` must restore service on
/// the same lanes.
#[test]
fn drain_with_multiple_flush_due_lanes_balances_every_lane() {
    let (backbone, _) = fixture();
    let mut s = FleetServer::new((*backbone).clone(), serve_cfg(4));

    // three tenants routed to three DISTINCT lanes, found via the same
    // SplitMix64 routing the LaneSet uses
    let mut tenants: Vec<u64> = Vec::new();
    let mut lanes_hit = std::collections::HashSet::new();
    for t in 0u64..64 {
        if lanes_hit.insert(lane_of(t, 4)) {
            tenants.push(t);
        }
        if tenants.len() == 3 {
            break;
        }
    }
    assert_eq!(tenants.len(), 3, "64 tenant ids must cover 3 of 4 lanes");

    // fill each tenant's lane exactly to batch capacity (serve_cfg sets
    // batch_capacity = 8), so all three lanes are flush-due when the
    // drain begins
    let mut rng = Rng::new(0xD12A);
    let mut submitted = 0usize;
    for &t in &tenants {
        for _ in 0..8 {
            let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.uniform(-1.0, 1.0)).collect();
            match s.handle(t, Request::Predict(x)) {
                Response::Queued { .. } => submitted += 1,
                other => panic!("admission failed: {other:?}"),
            }
        }
    }
    let before = s.obs_snapshot();
    let loaded = before.lanes.iter().filter(|l| l.queued > 0).count();
    assert!(loaded >= 2, "setup must leave >=2 lanes loaded, got {loaded}");

    let report = s.drain();
    assert_eq!(report.queued_at_start, submitted);
    assert_eq!(report.completions.len(), submitted, "drain lost requests");

    // every lane's books close: nothing queued, completed == admitted
    let snap = s.obs_snapshot();
    assert_eq!(snap.lanes.len(), 4);
    for l in &snap.lanes {
        assert_eq!(l.queued, 0, "lane {} still queued after drain", l.lane);
        assert_eq!(l.completed, l.admitted, "lane {} books", l.lane);
    }

    // admissions are closed with the typed reason...
    let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.uniform(-1.0, 1.0)).collect();
    match s.handle(tenants[0], Request::Predict(x.clone())) {
        Response::Rejected(RejectReason::Draining) => {}
        other => panic!("drained server must reject with Draining, got {other:?}"),
    }

    // ...and resume_admissions restores service on the same lanes
    s.resume_admissions();
    match s.handle(tenants[0], Request::Predict(x)) {
        Response::Queued { .. } => {}
        other => panic!("resumed server must admit, got {other:?}"),
    }
    let done = s.pump_until_drained();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].tenant, tenants[0]);
    assert_eq!(s.stats().queued, 0);
}

// ---------------------------------------------------------------------
// stress: publishers churn versions while lanes pump
// ---------------------------------------------------------------------

/// Concurrent publishers bump adapter versions for a small tenant set
/// while observer threads each drive their OWN lane set over the shared
/// registry. Every observer asserts per-tenant `adapter_version`
/// monotonicity across its served responses — lane routing must never
/// reorder a tenant's snapshot history.
#[test]
fn adapter_versions_stay_monotone_per_tenant_while_lanes_pump() {
    const N_TENANTS: u64 = 6;
    let mut rng = Rng::new(0x57_AE55);
    let backbone = Arc::new(Mlp::new(
        &mut rng,
        MlpConfig { dims: DIMS.to_vec(), rank: 4, batch_norm: true },
    ));
    let registry = Arc::new(AdapterRegistry::with_shards(4));
    let shared = (Arc::clone(&backbone), Arc::clone(&registry));
    let cfg = StressConfig { workers: 3, ops: 60, observers: 2, seed: 0x1A7E };

    let report = stress::run(
        &cfg,
        &shared,
        // publishers: churn adapter versions for the shared tenant set
        |mut ctx, (_, reg): &(Arc<Mlp>, Arc<AdapterRegistry>)| {
            let mut published = 0u64;
            for _ in 0..ctx.ops {
                let t = ctx.rng.below(N_TENANTS as usize) as u64;
                let ads: Vec<LoraAdapter> = DIMS[..DIMS.len() - 1]
                    .iter()
                    .map(|&n_in| LoraAdapter::new(&mut ctx.rng, n_in, 4, DIMS[3]))
                    .collect();
                reg.publish(t, ads);
                published += 1;
            }
            published
        },
        // observers: each owns a 4-lane set and pumps while churn runs
        |mut ctx, (bb, reg): &(Arc<Mlp>, Arc<AdapterRegistry>)| {
            let mut lanes = LaneSet::new(4, 32, true, |_| {
                let frozen = FrozenBackbone::new(Arc::clone(bb), Backend::Blocked, 4);
                MicroBatcher::with_limits(frozen, Arc::clone(reg), 2, 4096)
            });
            let mut last_version = vec![0u64; N_TENANTS as usize];
            let mut out = Vec::new();
            let mut flushes = Vec::new();
            let mut served = 0u64;
            let mut id = 0u64;
            while ctx.workers_live() {
                for _ in 0..4 {
                    id += 1;
                    let t = ctx.rng.below(N_TENANTS as usize) as u64;
                    let x: Vec<f32> =
                        (0..DIMS[0]).map(|_| ctx.rng.uniform(-1.0, 1.0)).collect();
                    let _ = lanes.try_submit(BatchRequest { tenant: t, id, x, label: None });
                }
                out.clear();
                lanes.pump(&mut out, &mut flushes, None);
                for resp in &out {
                    let slot = &mut last_version[resp.tenant as usize];
                    assert!(
                        resp.adapter_version >= *slot,
                        "tenant {}: version {} < previously served {}",
                        resp.tenant,
                        resp.adapter_version,
                        *slot
                    );
                    *slot = resp.adapter_version;
                    served += 1;
                }
                assert!(lanes.balanced(), "lane books unbalanced under churn");
            }
            // publishers are gone: drain the stragglers deterministically
            // (pump would wait out the deadline; flush_all won't)
            out.clear();
            lanes.flush_all(&mut out);
            for resp in &out {
                let slot = &mut last_version[resp.tenant as usize];
                assert!(resp.adapter_version >= *slot, "stale snapshot after drain");
                *slot = resp.adapter_version;
                served += 1;
            }
            assert_eq!(lanes.pending(), 0);
            assert!(lanes.balanced(), "final lane books unbalanced");
            served
        },
    );

    assert_eq!(report.workers.iter().sum::<u64>(), 3 * 60);
    assert!(
        report.observers.iter().all(|&served| served > 0),
        "every observer must have served rows during churn"
    );
}
