//! Adversarial tests for the `skip2lora/wire/v1` protocol: every hostile
//! byte sequence must produce a TYPED error (or a typed rejection), never
//! a panic, a hang, or a silent mis-parse. Same contract the `.s2l`
//! parser holds (`model/io.rs`), applied to the network boundary — plus
//! live handshake checks against a real `NodeServer` over loopback.

use skip2lora::net::wire::{
    decode_request, decode_response, encode_request, encode_response, read_frame, read_response,
    write_frame, write_request, WireCompletion, WireRequest, WireResponse, MAGIC, MAX_FRAME_BYTES,
    WIRE_VERSION,
};
use skip2lora::net::NodeServer;
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::serve::server::RejectReason;
use skip2lora::serve::{FleetServer, ServeConfig};
use skip2lora::tensor::Mat;

// ---------------------------------------------------------------------------
// codec corpus

fn request_corpus() -> Vec<WireRequest> {
    let adapter = LoraAdapter {
        wa: Mat::from_vec(4, 2, vec![0.5; 8]),
        wb: Mat::from_vec(2, 3, vec![-1.25; 6]),
    };
    vec![
        WireRequest::Hello {
            version: WIRE_VERSION,
        },
        WireRequest::Predict {
            tenant: 11,
            x: vec![1.0, 2.0, 3.0, 4.0],
        },
        WireRequest::Feedback {
            tenant: 0,
            x: vec![0.25; 8],
            label: 1,
        },
        WireRequest::SwapAdapters {
            tenant: 3,
            adapters: vec![adapter],
        },
        WireRequest::Observe,
        WireRequest::SaveState {
            path: "/tmp/fleet.s2l".into(),
        },
        WireRequest::RestoreState {
            path: "/tmp/fleet.s2l".into(),
        },
        WireRequest::ExportTenant { tenant: 5 },
        WireRequest::ImportTenant {
            bytes: b"S2L1....".to_vec(),
        },
        WireRequest::Drain,
        WireRequest::Pump,
        WireRequest::PumpDrain,
        WireRequest::QueueDepth,
        WireRequest::Resume,
    ]
}

fn response_corpus() -> Vec<WireResponse> {
    let c = WireCompletion {
        tenant: 9,
        ticket: 100,
        prediction: 1,
        label: Some(2),
        correct: Some(true),
        adapter_version: 3,
    };
    vec![
        WireResponse::HelloOk {
            version: WIRE_VERSION,
        },
        WireResponse::Queued { ticket: 1 },
        WireResponse::Rejected(RejectReason::QueueFull { bound: 64 }),
        WireResponse::Rejected(RejectReason::Malformed("bad dim".into())),
        WireResponse::Rejected(RejectReason::Draining),
        WireResponse::Swapped { version: 2 },
        WireResponse::Observed {
            json: "{\"a\":1}".into(),
        },
        WireResponse::Persisted {
            tenants: 1,
            bytes: 128,
        },
        WireResponse::Restored {
            tenants: 1,
            installed: 1,
            max_version: 4,
        },
        WireResponse::TenantExported {
            bytes: vec![0, 1, 2],
        },
        WireResponse::TenantImported {
            tenant: 9,
            version: 5,
        },
        WireResponse::Drained {
            queued_at_start: 1,
            finetunes_joined: 0,
            completions: vec![c.clone()],
        },
        WireResponse::Completions(vec![c]),
        WireResponse::QueueDepthOk { queued: 0 },
        WireResponse::Resumed,
        WireResponse::Error { msg: "boom".into() },
    ]
}

// ---------------------------------------------------------------------------
// truncation sweeps — EVERY strict prefix of every frame must fail typed

#[test]
fn every_request_prefix_is_rejected_not_panicked() {
    for req in request_corpus() {
        let body = encode_request(&req);
        for cut in 0..body.len() {
            let r = decode_request(&body[..cut]);
            assert!(r.is_err(), "{req:?} decoded from a {cut}-byte prefix");
        }
        assert!(decode_request(&body).is_ok(), "{req:?} full frame failed");
    }
}

#[test]
fn every_response_prefix_is_rejected_not_panicked() {
    for resp in response_corpus() {
        let body = encode_response(&resp);
        for cut in 0..body.len() {
            let r = decode_response(&body[..cut]);
            assert!(r.is_err(), "{resp:?} decoded from a {cut}-byte prefix");
        }
        assert!(decode_response(&body).is_ok(), "{resp:?} full frame failed");
    }
}

#[test]
fn every_stream_prefix_is_rejected_not_panicked() {
    // truncation at the STREAM layer: cut mid-length-prefix and mid-body
    for req in request_corpus() {
        let mut stream = Vec::new();
        write_request(&mut stream, &req).unwrap();
        for cut in 0..stream.len() {
            let r = read_frame(&mut &stream[..cut]);
            assert!(r.is_err(), "{req:?} stream prefix {cut} accepted");
        }
    }
}

// ---------------------------------------------------------------------------
// hostile frames

#[test]
fn trailing_bytes_after_any_frame_are_rejected() {
    for req in request_corpus() {
        let mut body = encode_request(&req);
        body.extend_from_slice(&[0xAB, 0xCD]);
        assert!(decode_request(&body).is_err(), "{req:?} took trailing bytes");
    }
    for resp in response_corpus() {
        let mut body = encode_response(&resp);
        body.push(0xEE);
        assert!(
            decode_response(&body).is_err(),
            "{resp:?} took trailing bytes"
        );
    }
}

#[test]
fn unknown_frame_tags_are_typed_errors() {
    // 0x00 is never assigned; 0x40 unused request; 0xC0 unused response
    for tag in [0x00u8, 0x40, 0x7F] {
        let err = decode_request(&[tag]).unwrap_err().to_string();
        assert!(err.contains("unknown request frame tag"), "{err}");
    }
    for tag in [0x00u8, 0xC0, 0xFE] {
        let err = decode_response(&[tag]).unwrap_err().to_string();
        assert!(err.contains("unknown response frame tag"), "{err}");
    }
}

#[test]
fn oversized_and_zero_length_prefixes_are_rejected() {
    let mut s = Vec::new();
    s.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(read_frame(&mut s.as_slice())
        .unwrap_err()
        .to_string()
        .contains("MAX_FRAME_BYTES"));

    let over = (MAX_FRAME_BYTES as u32) + 1;
    let mut s = Vec::new();
    s.extend_from_slice(&over.to_le_bytes());
    assert!(read_frame(&mut s.as_slice()).is_err());

    let s = 0u32.to_le_bytes();
    assert!(read_frame(&mut s.as_slice())
        .unwrap_err()
        .to_string()
        .contains("zero-length"));
}

#[test]
fn writer_refuses_oversized_and_empty_frames() {
    let mut sink = Vec::new();
    assert!(write_frame(&mut sink, &[]).is_err());
    let huge = vec![0u8; MAX_FRAME_BYTES + 1];
    assert!(write_frame(&mut sink, &huge).is_err());
    assert!(sink.is_empty(), "a refused frame must write NOTHING");
}

#[test]
fn hostile_counts_cannot_wrap_or_overallocate() {
    // Predict claiming u32::MAX floats in a tiny frame
    let mut body = vec![0x02u8];
    body.extend_from_slice(&1u64.to_le_bytes());
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    body.extend_from_slice(&[0u8; 8]);
    assert!(decode_request(&body).is_err());

    // SwapAdapters with dims whose product overflows usize on 32-bit
    // and whose byte count overflows even on 64-bit
    let mut body = vec![0x04u8];
    body.extend_from_slice(&1u64.to_le_bytes()); // tenant
    body.extend_from_slice(&1u32.to_le_bytes()); // 1 adapter
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // n_in
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // rank
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // n_out
    assert!(decode_request(&body).is_err());

    // ImportTenant announcing more payload bytes than the frame holds
    let mut body = vec![0x09u8];
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    body.extend_from_slice(&[7u8; 3]);
    assert!(decode_request(&body).is_err());
}

#[test]
fn corrupt_option_bytes_in_completions_are_rejected() {
    let good = WireResponse::Completions(vec![WireCompletion {
        tenant: 1,
        ticket: 2,
        prediction: 0,
        label: None,
        correct: None,
        adapter_version: 0,
    }]);
    let body = encode_response(&good);
    // completion layout: tag(1) count(4) tenant(8) ticket(8) pred(4)
    // label-presence(1) correct(1) version(8)
    let label_presence = 1 + 4 + 8 + 8 + 4;
    for bad in [2u8, 0xFF] {
        let mut b = body.clone();
        b[label_presence] = bad;
        assert!(decode_response(&b).is_err(), "presence byte {bad} accepted");
    }
    let correct_byte = label_presence + 1;
    for bad in [3u8, 0xFF] {
        let mut b = body.clone();
        b[correct_byte] = bad;
        assert!(decode_response(&b).is_err(), "correct byte {bad} accepted");
    }
}

#[test]
fn non_utf8_strings_are_rejected() {
    // SaveState with invalid UTF-8 path bytes
    let mut body = vec![0x06u8];
    body.extend_from_slice(&2u32.to_le_bytes());
    body.extend_from_slice(&[0xFF, 0xFE]);
    let err = decode_request(&body).unwrap_err().to_string();
    assert!(err.contains("non-UTF-8"), "{err}");
}

#[test]
fn bad_hello_magic_is_rejected() {
    let mut body = vec![0x01u8];
    body.extend_from_slice(b"NOPE");
    body.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    let err = decode_request(&body).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");
    // and the genuine magic still parses
    let mut body = vec![0x01u8];
    body.extend_from_slice(MAGIC);
    body.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    assert!(decode_request(&body).is_ok());
}

#[test]
fn random_garbage_never_panics() {
    // deterministic xorshift garbage, many lengths — decoding must
    // always return (Ok or Err), never panic or loop
    let mut state = 0x1234_5678_9ABC_DEF0u64;
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    for len in 0..200usize {
        let mut bytes = vec![0u8; len];
        for b in bytes.iter_mut() {
            *b = next() as u8;
        }
        let _ = decode_request(&bytes);
        let _ = decode_response(&bytes);
        let _ = read_frame(&mut bytes.as_slice());
    }
}

// ---------------------------------------------------------------------------
// live handshake behavior (loopback, tiny backbone)

fn tiny_server() -> FleetServer {
    use skip2lora::data::Dataset;
    use skip2lora::model::MlpConfig;
    use skip2lora::tensor::ops::Backend;
    use skip2lora::train::trainer::pretrain;

    let x = Mat::from_vec(4, 4, vec![0.1; 16]);
    let data = Dataset {
        x,
        labels: vec![0, 1, 0, 1],
        n_classes: 2,
    };
    let cfg = MlpConfig {
        dims: vec![4, 6, 2],
        rank: 1,
        batch_norm: false,
    };
    let backbone = pretrain(cfg, &data, 5, 0.05, 1, Backend::Blocked);
    FleetServer::new(
        backbone,
        ServeConfig {
            workers: 0,
            ..Default::default()
        },
    )
}

#[test]
fn version_mismatch_handshake_is_refused_with_a_typed_error() {
    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write_request(
        &mut stream,
        &WireRequest::Hello {
            version: WIRE_VERSION + 1,
        },
    )
    .unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("version mismatch"), "{msg}"),
        other => panic!("expected Error, got {other:?}"),
    }
    drop(stream);
    node.shutdown();
}

#[test]
fn first_frame_must_be_hello() {
    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write_request(&mut stream, &WireRequest::QueueDepth).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("Hello"), "{msg}"),
        other => panic!("expected Error, got {other:?}"),
    }
    drop(stream);
    node.shutdown();
}

#[test]
fn duplicate_hello_is_refused_but_connection_survives() {
    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let hello = WireRequest::Hello {
        version: WIRE_VERSION,
    };
    write_request(&mut stream, &hello).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::HelloOk { version } => assert_eq!(version, WIRE_VERSION),
        other => panic!("{other:?}"),
    }
    // a second Hello is a protocol error...
    write_request(&mut stream, &hello).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("duplicate Hello"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // ...but framing survived, so the connection keeps working
    write_request(&mut stream, &WireRequest::QueueDepth).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::QueueDepthOk { queued } => assert_eq!(queued, 0),
        other => panic!("{other:?}"),
    }
    drop(stream);
    node.shutdown();
}

#[test]
fn malformed_frame_mid_session_gets_typed_error_and_session_continues() {
    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write_request(
        &mut stream,
        &WireRequest::Hello {
            version: WIRE_VERSION,
        },
    )
    .unwrap();
    let _ = read_response(&mut stream).unwrap();

    // well-framed but undecodable: unknown tag inside a valid frame
    write_frame(&mut stream, &[0x40u8, 1, 2, 3]).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("unknown request"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // truncated payload inside a valid frame
    write_frame(&mut stream, &[0x02u8, 0, 0]).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("truncated"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // the session still serves real frames afterwards
    write_request(&mut stream, &WireRequest::QueueDepth).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::QueueDepthOk { .. } => {}
        other => panic!("{other:?}"),
    }
    drop(stream);
    node.shutdown();
}

#[test]
fn interleaved_connections_do_not_cross_frames() {
    // two clients alternating requests against one node: responses must
    // pair with the requesting connection, never leak across
    use skip2lora::net::NodeClient;

    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();
    let mut a = NodeClient::connect(&addr).unwrap();
    let mut b = NodeClient::connect(&addr).unwrap();
    for i in 0..10u64 {
        match a.predict(i, vec![0.1, 0.2, 0.3, 0.4]).unwrap() {
            skip2lora::net::Admission::Queued { .. } => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(b.queue_depth().unwrap(), (i + 1) as usize);
    }
    let done = a.pump_drain().unwrap();
    assert_eq!(done.len(), 10);
    assert_eq!(b.queue_depth().unwrap(), 0);
    drop(a);
    drop(b);
    node.shutdown();
}
