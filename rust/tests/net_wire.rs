//! Adversarial tests for the `skip2lora/wire/v1` protocol: every hostile
//! byte sequence must produce a TYPED error (or a typed rejection), never
//! a panic, a hang, or a silent mis-parse. Same contract the `.s2l`
//! parser holds (`model/io.rs`), applied to the network boundary — plus
//! live handshake checks against a real `NodeServer` over loopback.

use skip2lora::net::wire::{
    decode_request, decode_response, encode_request, encode_response, read_frame, read_response,
    write_frame, write_request, WireCompletion, WireRequest, WireResponse, MAGIC, MAX_FRAME_BYTES,
    WIRE_VERSION,
};
use skip2lora::net::NodeServer;
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::serve::server::RejectReason;
use skip2lora::serve::{FleetServer, ServeConfig};
use skip2lora::tensor::Mat;

// ---------------------------------------------------------------------------
// codec corpus

fn request_corpus() -> Vec<WireRequest> {
    let adapter = LoraAdapter {
        wa: Mat::from_vec(4, 2, vec![0.5; 8]),
        wb: Mat::from_vec(2, 3, vec![-1.25; 6]),
    };
    vec![
        WireRequest::Hello {
            version: WIRE_VERSION,
            token: None,
            client_id: 0,
        },
        WireRequest::Hello {
            version: WIRE_VERSION,
            token: Some("shared-secret".into()),
            client_id: 77,
        },
        WireRequest::Predict {
            tenant: 11,
            x: vec![1.0, 2.0, 3.0, 4.0],
            req_id: 0,
        },
        WireRequest::Feedback {
            tenant: 0,
            x: vec![0.25; 8],
            label: 1,
            req_id: u64::MAX,
        },
        WireRequest::SwapAdapters {
            tenant: 3,
            adapters: vec![adapter],
        },
        WireRequest::Observe,
        WireRequest::SaveState {
            path: "/tmp/fleet.s2l".into(),
        },
        WireRequest::RestoreState {
            path: "/tmp/fleet.s2l".into(),
        },
        WireRequest::ExportTenant { tenant: 5 },
        WireRequest::ImportTenant {
            bytes: b"S2L1....".to_vec(),
        },
        WireRequest::Drain,
        WireRequest::Pump,
        WireRequest::PumpDrain,
        WireRequest::QueueDepth,
        WireRequest::Resume,
    ]
}

fn response_corpus() -> Vec<WireResponse> {
    let c = WireCompletion {
        tenant: 9,
        ticket: 100,
        prediction: 1,
        label: Some(2),
        correct: Some(true),
        adapter_version: 3,
    };
    vec![
        WireResponse::HelloOk {
            version: WIRE_VERSION,
        },
        WireResponse::Queued { ticket: 1 },
        WireResponse::Rejected(RejectReason::QueueFull { bound: 64 }),
        WireResponse::Rejected(RejectReason::Malformed("bad dim".into())),
        WireResponse::Rejected(RejectReason::Draining),
        WireResponse::Swapped { version: 2 },
        WireResponse::Observed {
            json: "{\"a\":1}".into(),
        },
        WireResponse::Persisted {
            tenants: 1,
            bytes: 128,
        },
        WireResponse::Restored {
            tenants: 1,
            installed: 1,
            max_version: 4,
        },
        WireResponse::TenantExported {
            bytes: vec![0, 1, 2],
        },
        WireResponse::TenantImported {
            tenant: 9,
            version: 5,
        },
        WireResponse::Drained {
            queued_at_start: 1,
            finetunes_joined: 0,
            completions: vec![c.clone()],
        },
        WireResponse::Completions(vec![c]),
        WireResponse::QueueDepthOk { queued: 0 },
        WireResponse::Resumed,
        WireResponse::Unauthorized,
        WireResponse::Busy { limit: 64 },
        WireResponse::Error { msg: "boom".into() },
    ]
}

// ---------------------------------------------------------------------------
// truncation sweeps — EVERY strict prefix of every frame must fail typed

#[test]
fn every_request_prefix_is_rejected_not_panicked() {
    for req in request_corpus() {
        let body = encode_request(&req);
        for cut in 0..body.len() {
            let r = decode_request(&body[..cut]);
            assert!(r.is_err(), "{req:?} decoded from a {cut}-byte prefix");
        }
        assert!(decode_request(&body).is_ok(), "{req:?} full frame failed");
    }
}

#[test]
fn every_response_prefix_is_rejected_not_panicked() {
    for resp in response_corpus() {
        let body = encode_response(&resp);
        for cut in 0..body.len() {
            let r = decode_response(&body[..cut]);
            assert!(r.is_err(), "{resp:?} decoded from a {cut}-byte prefix");
        }
        assert!(decode_response(&body).is_ok(), "{resp:?} full frame failed");
    }
}

#[test]
fn every_stream_prefix_is_rejected_not_panicked() {
    // truncation at the STREAM layer: cut mid-length-prefix and mid-body
    for req in request_corpus() {
        let mut stream = Vec::new();
        write_request(&mut stream, &req).unwrap();
        for cut in 0..stream.len() {
            let r = read_frame(&mut &stream[..cut]);
            assert!(r.is_err(), "{req:?} stream prefix {cut} accepted");
        }
    }
}

// ---------------------------------------------------------------------------
// hostile frames

#[test]
fn trailing_bytes_after_any_frame_are_rejected() {
    for req in request_corpus() {
        let mut body = encode_request(&req);
        body.extend_from_slice(&[0xAB, 0xCD]);
        assert!(decode_request(&body).is_err(), "{req:?} took trailing bytes");
    }
    for resp in response_corpus() {
        let mut body = encode_response(&resp);
        body.push(0xEE);
        assert!(
            decode_response(&body).is_err(),
            "{resp:?} took trailing bytes"
        );
    }
}

#[test]
fn unknown_frame_tags_are_typed_errors() {
    // 0x00 is never assigned; 0x40 unused request; 0xC0 unused response
    for tag in [0x00u8, 0x40, 0x7F] {
        let err = decode_request(&[tag]).unwrap_err().to_string();
        assert!(err.contains("unknown request frame tag"), "{err}");
    }
    for tag in [0x00u8, 0xC0, 0xFE] {
        let err = decode_response(&[tag]).unwrap_err().to_string();
        assert!(err.contains("unknown response frame tag"), "{err}");
    }
}

#[test]
fn oversized_and_zero_length_prefixes_are_rejected() {
    let mut s = Vec::new();
    s.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(read_frame(&mut s.as_slice())
        .unwrap_err()
        .to_string()
        .contains("MAX_FRAME_BYTES"));

    let over = (MAX_FRAME_BYTES as u32) + 1;
    let mut s = Vec::new();
    s.extend_from_slice(&over.to_le_bytes());
    assert!(read_frame(&mut s.as_slice()).is_err());

    let s = 0u32.to_le_bytes();
    assert!(read_frame(&mut s.as_slice())
        .unwrap_err()
        .to_string()
        .contains("zero-length"));
}

#[test]
fn writer_refuses_oversized_and_empty_frames() {
    let mut sink = Vec::new();
    assert!(write_frame(&mut sink, &[]).is_err());
    let huge = vec![0u8; MAX_FRAME_BYTES + 1];
    assert!(write_frame(&mut sink, &huge).is_err());
    assert!(sink.is_empty(), "a refused frame must write NOTHING");
}

#[test]
fn hostile_counts_cannot_wrap_or_overallocate() {
    // Predict claiming u32::MAX floats in a tiny frame
    let mut body = vec![0x02u8];
    body.extend_from_slice(&1u64.to_le_bytes());
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    body.extend_from_slice(&[0u8; 8]);
    assert!(decode_request(&body).is_err());

    // SwapAdapters with dims whose product overflows usize on 32-bit
    // and whose byte count overflows even on 64-bit
    let mut body = vec![0x04u8];
    body.extend_from_slice(&1u64.to_le_bytes()); // tenant
    body.extend_from_slice(&1u32.to_le_bytes()); // 1 adapter
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // n_in
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // rank
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // n_out
    assert!(decode_request(&body).is_err());

    // ImportTenant announcing more payload bytes than the frame holds
    let mut body = vec![0x09u8];
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    body.extend_from_slice(&[7u8; 3]);
    assert!(decode_request(&body).is_err());
}

#[test]
fn corrupt_option_bytes_in_completions_are_rejected() {
    let good = WireResponse::Completions(vec![WireCompletion {
        tenant: 1,
        ticket: 2,
        prediction: 0,
        label: None,
        correct: None,
        adapter_version: 0,
    }]);
    let body = encode_response(&good);
    // completion layout: tag(1) count(4) tenant(8) ticket(8) pred(4)
    // label-presence(1) correct(1) version(8)
    let label_presence = 1 + 4 + 8 + 8 + 4;
    for bad in [2u8, 0xFF] {
        let mut b = body.clone();
        b[label_presence] = bad;
        assert!(decode_response(&b).is_err(), "presence byte {bad} accepted");
    }
    let correct_byte = label_presence + 1;
    for bad in [3u8, 0xFF] {
        let mut b = body.clone();
        b[correct_byte] = bad;
        assert!(decode_response(&b).is_err(), "correct byte {bad} accepted");
    }
}

#[test]
fn non_utf8_strings_are_rejected() {
    // SaveState with invalid UTF-8 path bytes
    let mut body = vec![0x06u8];
    body.extend_from_slice(&2u32.to_le_bytes());
    body.extend_from_slice(&[0xFF, 0xFE]);
    let err = decode_request(&body).unwrap_err().to_string();
    assert!(err.contains("non-UTF-8"), "{err}");
}

#[test]
fn bad_hello_magic_is_rejected() {
    let mut body = vec![0x01u8];
    body.extend_from_slice(b"NOPE");
    body.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    let err = decode_request(&body).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");
    // and the genuine magic still parses (v2 layout: magic, version,
    // token presence byte, client_id)
    let mut body = vec![0x01u8];
    body.extend_from_slice(MAGIC);
    body.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    body.push(0); // no token
    body.extend_from_slice(&0u64.to_le_bytes());
    assert!(decode_request(&body).is_ok());
}

#[test]
fn random_garbage_never_panics() {
    // deterministic xorshift garbage, many lengths — decoding must
    // always return (Ok or Err), never panic or loop
    let mut state = 0x1234_5678_9ABC_DEF0u64;
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    for len in 0..200usize {
        let mut bytes = vec![0u8; len];
        for b in bytes.iter_mut() {
            *b = next() as u8;
        }
        let _ = decode_request(&bytes);
        let _ = decode_response(&bytes);
        let _ = read_frame(&mut bytes.as_slice());
    }
}

// ---------------------------------------------------------------------------
// live handshake behavior (loopback, tiny backbone)

fn tiny_server() -> FleetServer {
    use skip2lora::data::Dataset;
    use skip2lora::model::MlpConfig;
    use skip2lora::tensor::ops::Backend;
    use skip2lora::train::trainer::pretrain;

    let x = Mat::from_vec(4, 4, vec![0.1; 16]);
    let data = Dataset {
        x,
        labels: vec![0, 1, 0, 1],
        n_classes: 2,
    };
    let cfg = MlpConfig {
        dims: vec![4, 6, 2],
        rank: 1,
        batch_norm: false,
    };
    let backbone = pretrain(cfg, &data, 5, 0.05, 1, Backend::Blocked);
    FleetServer::new(
        backbone,
        ServeConfig {
            workers: 0,
            ..Default::default()
        },
    )
}

#[test]
fn version_mismatch_handshake_is_refused_with_a_typed_error() {
    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write_request(
        &mut stream,
        &WireRequest::Hello {
            version: WIRE_VERSION + 1,
            token: None,
            client_id: 0,
        },
    )
    .unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("version mismatch"), "{msg}"),
        other => panic!("expected Error, got {other:?}"),
    }
    drop(stream);
    node.shutdown();
}

#[test]
fn first_frame_must_be_hello() {
    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write_request(&mut stream, &WireRequest::QueueDepth).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("Hello"), "{msg}"),
        other => panic!("expected Error, got {other:?}"),
    }
    drop(stream);
    node.shutdown();
}

#[test]
fn duplicate_hello_is_refused_but_connection_survives() {
    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let hello = WireRequest::Hello {
        version: WIRE_VERSION,
        token: None,
        client_id: 0,
    };
    write_request(&mut stream, &hello).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::HelloOk { version } => assert_eq!(version, WIRE_VERSION),
        other => panic!("{other:?}"),
    }
    // a second Hello is a protocol error...
    write_request(&mut stream, &hello).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("duplicate Hello"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // ...but framing survived, so the connection keeps working
    write_request(&mut stream, &WireRequest::QueueDepth).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::QueueDepthOk { queued } => assert_eq!(queued, 0),
        other => panic!("{other:?}"),
    }
    drop(stream);
    node.shutdown();
}

#[test]
fn malformed_frame_mid_session_gets_typed_error_and_session_continues() {
    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write_request(
        &mut stream,
        &WireRequest::Hello {
            version: WIRE_VERSION,
            token: None,
            client_id: 0,
        },
    )
    .unwrap();
    let _ = read_response(&mut stream).unwrap();

    // well-framed but undecodable: unknown tag inside a valid frame
    write_frame(&mut stream, &[0x40u8, 1, 2, 3]).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("unknown request"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // truncated payload inside a valid frame
    write_frame(&mut stream, &[0x02u8, 0, 0]).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("truncated"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // the session still serves real frames afterwards
    write_request(&mut stream, &WireRequest::QueueDepth).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::QueueDepthOk { .. } => {}
        other => panic!("{other:?}"),
    }
    drop(stream);
    node.shutdown();
}

#[test]
fn interleaved_connections_do_not_cross_frames() {
    // two clients alternating requests against one node: responses must
    // pair with the requesting connection, never leak across
    use skip2lora::net::NodeClient;

    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();
    let mut a = NodeClient::connect(&addr).unwrap();
    let mut b = NodeClient::connect(&addr).unwrap();
    for i in 0..10u64 {
        match a.predict(i, vec![0.1, 0.2, 0.3, 0.4]).unwrap() {
            skip2lora::net::Admission::Queued { .. } => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(b.queue_depth().unwrap(), (i + 1) as usize);
    }
    let done = a.pump_drain().unwrap();
    assert_eq!(done.len(), 10);
    assert_eq!(b.queue_depth().unwrap(), 0);
    drop(a);
    drop(b);
    node.shutdown();
}

// ---------------------------------------------------------------------------
// auth, connection caps, idle reaping, mid-frame death (PR: fleet plane
// hardening — DESIGN.md §15)

#[test]
fn wrong_or_missing_auth_token_is_refused_before_any_verb() {
    use skip2lora::net::NodeServerConfig;

    let node = NodeServer::spawn_with(
        tiny_server(),
        "127.0.0.1:0",
        NodeServerConfig {
            auth_token: Some("open-sesame".into()),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = node.addr().to_string();

    // missing token, wrong token: typed Unauthorized, connection closed
    for token in [None, Some("open-says-me".to_string())] {
        let mut stream = std::net::TcpStream::connect(&addr).unwrap();
        write_request(
            &mut stream,
            &WireRequest::Hello {
                version: WIRE_VERSION,
                token,
                client_id: 0,
            },
        )
        .unwrap();
        match read_response(&mut stream).unwrap() {
            WireResponse::Unauthorized => {}
            other => panic!("expected Unauthorized, got {other:?}"),
        }
        // the server hung up — no verb gets through on this connection
        let _ = write_request(&mut stream, &WireRequest::QueueDepth);
        assert!(
            read_response(&mut stream).is_err(),
            "unauthorized connection must not serve verbs"
        );
    }

    // an adversary skipping Hello entirely learns only the Hello rule
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write_request(&mut stream, &WireRequest::Observe).unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Error { msg } => assert!(msg.contains("Hello"), "{msg}"),
        other => panic!("expected Error, got {other:?}"),
    }

    // and the right token serves normally
    let mut client = skip2lora::net::NodeClient::connect_with(
        &addr,
        skip2lora::net::ClientConfig {
            token: Some("open-sesame".into()),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(client.queue_depth().unwrap(), 0);
    drop(client);
    node.shutdown();
}

#[test]
fn connection_cap_answers_busy_with_the_limit() {
    use skip2lora::net::NodeServerConfig;

    let node = NodeServer::spawn_with(
        tiny_server(),
        "127.0.0.1:0",
        NodeServerConfig {
            max_connections: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let addr = node.addr().to_string();

    let mut first = skip2lora::net::NodeClient::connect(&addr).unwrap();
    assert_eq!(first.queue_depth().unwrap(), 0);

    // the second concurrent connection is over the cap: typed Busy
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write_request(
        &mut stream,
        &WireRequest::Hello {
            version: WIRE_VERSION,
            token: None,
            client_id: 0,
        },
    )
    .unwrap();
    match read_response(&mut stream).unwrap() {
        WireResponse::Busy { limit } => assert_eq!(limit, 1),
        other => panic!("expected Busy, got {other:?}"),
    }
    drop(stream);

    // once the first connection closes, a newcomer gets a slot (the
    // accept loop reaps finished handlers; poll briefly for the slot)
    drop(first);
    let mut ok = false;
    for _ in 0..100 {
        if let Ok(mut c) = skip2lora::net::NodeClient::connect(&addr) {
            if c.queue_depth().is_ok() {
                ok = true;
                break;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(ok, "slot never freed after the first connection closed");
    node.shutdown();
}

#[test]
fn idle_connections_are_reaped_after_the_timeout() {
    use skip2lora::net::NodeServerConfig;

    let node = NodeServer::spawn_with(
        tiny_server(),
        "127.0.0.1:0",
        NodeServerConfig {
            idle_timeout: std::time::Duration::from_millis(75),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = node.addr().to_string();

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write_request(
        &mut stream,
        &WireRequest::Hello {
            version: WIRE_VERSION,
            token: None,
            client_id: 0,
        },
    )
    .unwrap();
    let _ = read_response(&mut stream).unwrap();

    // go silent past the idle budget: the server hangs up
    std::thread::sleep(std::time::Duration::from_millis(400));
    let _ = write_request(&mut stream, &WireRequest::QueueDepth);
    assert!(
        read_response(&mut stream).is_err(),
        "idle connection should have been reaped"
    );

    // an ACTIVE connection with the same config is untouched
    let mut client = skip2lora::net::NodeClient::connect(&addr).unwrap();
    for _ in 0..4 {
        std::thread::sleep(std::time::Duration::from_millis(40));
        assert_eq!(client.queue_depth().unwrap(), 0);
    }
    drop(client);
    node.shutdown();
}

#[test]
fn mid_frame_death_is_a_typed_retryable_error_never_a_hang() {
    use skip2lora::net::{ClientConfig, ClientError, NodeClient};
    use skip2lora::testkit::faults::{FaultPlan, FaultProxy, RespFault};

    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();
    // response ordinal 0 is the HelloOk; the predict response (ordinal
    // 1) dies 3 bytes in — "server killed while the client was reading"
    let proxy = FaultProxy::spawn(
        &addr,
        FaultPlan::transparent().fault_resp(1, RespFault::Cut { keep: 3 }),
    )
    .unwrap();

    let rpc_timeout = std::time::Duration::from_millis(500);
    let cfg = ClientConfig {
        rpc_timeout,
        ..Default::default()
    };
    let mut client = NodeClient::connect_with(proxy.addr(), cfg.clone()).unwrap();
    let start = std::time::Instant::now();
    let err = client
        .predict(7, vec![0.1, 0.2, 0.3, 0.4])
        .expect_err("cut response must fail");
    let elapsed = start.elapsed();
    match &err {
        ClientError::Transport(t) => assert!(t.retryable, "cut must be retryable: {t:?}"),
        other => panic!("expected a transport error, got {other:?}"),
    }
    assert!(err.is_retryable());
    assert!(client.is_broken(), "a torn stream must poison the client");
    assert!(
        elapsed < rpc_timeout + std::time::Duration::from_secs(2),
        "mid-frame death took {elapsed:?} — the client must never hang"
    );

    // a STALLED response (bytes stop flowing, connection stays open) is
    // bounded by rpc_timeout instead of hanging forever
    let proxy2 = FaultProxy::spawn(
        &addr,
        FaultPlan::transparent().fault_resp(1, RespFault::Stall { keep: 2 }),
    )
    .unwrap();
    let mut client2 = NodeClient::connect_with(proxy2.addr(), cfg).unwrap();
    let start = std::time::Instant::now();
    let err = client2
        .predict(7, vec![0.1, 0.2, 0.3, 0.4])
        .expect_err("stalled response must time out");
    let elapsed = start.elapsed();
    assert!(err.is_retryable(), "a timeout is retryable: {err:?}");
    assert!(
        elapsed < rpc_timeout * 4 + std::time::Duration::from_secs(2),
        "stall took {elapsed:?}, rpc_timeout is {rpc_timeout:?}"
    );

    proxy.shutdown();
    proxy2.shutdown();
    node.shutdown();
}

#[test]
fn retrying_a_req_id_replays_the_recorded_admission() {
    use skip2lora::net::{Admission, ClientConfig, NodeClient};

    let node = NodeServer::spawn(tiny_server(), "127.0.0.1:0").unwrap();
    let addr = node.addr().to_string();
    let mut client = NodeClient::connect_with(
        &addr,
        ClientConfig {
            client_id: 42,
            ..Default::default()
        },
    )
    .unwrap();

    let x = vec![0.1, 0.2, 0.3, 0.4];
    let first = match client.predict_req(7, x.clone(), 1001).unwrap() {
        Admission::Queued { ticket } => ticket,
        other => panic!("{other:?}"),
    };
    // the "retry after ambiguous outcome" path: same req_id replays the
    // RECORDED response instead of double-admitting
    match client.predict_req(7, x.clone(), 1001).unwrap() {
        Admission::Queued { ticket } => assert_eq!(ticket, first, "double admission!"),
        other => panic!("{other:?}"),
    }
    assert_eq!(client.queue_depth().unwrap(), 1, "dedupe must not re-queue");

    // a fresh req_id is a fresh admission
    match client.predict_req(7, x.clone(), 1002).unwrap() {
        Admission::Queued { ticket } => assert_ne!(ticket, first),
        other => panic!("{other:?}"),
    }
    assert_eq!(client.queue_depth().unwrap(), 2);

    // req_id 0 opts out of dedupe even with a client_id set
    let a = client.predict_req(7, x.clone(), 0).unwrap();
    let b = client.predict_req(7, x, 0).unwrap();
    match (a, b) {
        (Admission::Queued { ticket: ta }, Admission::Queued { ticket: tb }) => {
            assert_ne!(ta, tb, "req_id 0 must never dedupe");
        }
        other => panic!("{other:?}"),
    }
    assert_eq!(client.queue_depth().unwrap(), 4);
    drop(client);
    node.shutdown();
}
