//! Merge-law property tests for the fleet-telemetry primitives
//! (DESIGN.md §11): merging per-node `LatencyHistogram` / `Welford` /
//! `ServeMetrics` accumulators must reproduce the single-stream result —
//! counts and maxima bit-exactly, floating-point moments to within
//! rounding — and must be associative, because fleet aggregation happens
//! in whatever order snapshots arrive.

use skip2lora::serve::metrics::{LatencyHistogram, ServeMetrics};
use skip2lora::util::rng::Rng;
use skip2lora::util::stats::Welford;

/// Latency-shaped samples spanning many histogram buckets: a log-uniform
/// body (1µs..16ms) plus occasional extreme outliers into the tail.
fn latency_samples(seed: u64, n: usize) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            if rng.below(50) == 0 {
                // rare outlier: 100ms..1s, exercises the max-bucket path
                rng.range(100_000_000, 1_000_000_000) as u64
            } else {
                // log-uniform over ~14 buckets
                let exp = rng.uniform(10.0, 24.0);
                2f64.powf(exp as f64) as u64
            }
        })
        .collect()
}

fn hist_of(samples: &[u64]) -> LatencyHistogram {
    let mut h = LatencyHistogram::new();
    for &ns in samples {
        h.record_ns(ns);
    }
    h
}

#[test]
fn histogram_merge_equals_single_stream() {
    for seed in [1u64, 42, 0xBEEF, 7_777_777] {
        let samples = latency_samples(seed, 500);
        let whole = hist_of(&samples);
        // several split points, including degenerate ones
        for split in [0usize, 1, 250, 499, 500] {
            let mut a = hist_of(&samples[..split]);
            let b = hist_of(&samples[split..]);
            a.merge(&b);
            // discrete state is bit-exact
            assert_eq!(a.count(), whole.count(), "seed {seed} split {split}");
            assert_eq!(a.max_ns(), whole.max_ns(), "seed {seed} split {split}");
            assert_eq!(a.bucket_counts(), whole.bucket_counts(), "seed {seed} split {split}");
            // every percentile is derived from buckets + max, so it must
            // agree exactly once those do
            for p in [50.0, 95.0, 99.0, 100.0] {
                assert_eq!(a.percentile_ms(p), whole.percentile_ms(p), "p{p}");
            }
            // moments agree to rounding
            assert!((a.mean_ms() - whole.mean_ms()).abs() < 1e-9, "seed {seed}");
            assert!((a.std_ms() - whole.std_ms()).abs() < 1e-9, "seed {seed}");
        }
    }
}

#[test]
fn histogram_merge_is_associative() {
    let samples = latency_samples(99, 600);
    let (s1, s2, s3) = (&samples[..200], &samples[200..350], &samples[350..]);
    // (a ⊕ b) ⊕ c
    let mut left = hist_of(s1);
    left.merge(&hist_of(s2));
    left.merge(&hist_of(s3));
    // a ⊕ (b ⊕ c)
    let mut bc = hist_of(s2);
    bc.merge(&hist_of(s3));
    let mut right = hist_of(s1);
    right.merge(&bc);
    assert_eq!(left.count(), right.count());
    assert_eq!(left.max_ns(), right.max_ns());
    assert_eq!(left.bucket_counts(), right.bucket_counts());
    assert!((left.mean_ms() - right.mean_ms()).abs() < 1e-9);
    assert!((left.std_ms() - right.std_ms()).abs() < 1e-9);
}

#[test]
fn histogram_merge_empty_is_identity() {
    let samples = latency_samples(5, 100);
    let whole = hist_of(&samples);
    // empty ⊕ x == x
    let mut left = LatencyHistogram::new();
    left.merge(&whole);
    assert_eq!(left.count(), whole.count());
    assert_eq!(left.bucket_counts(), whole.bucket_counts());
    assert_eq!(left.max_ns(), whole.max_ns());
    // x ⊕ empty == x
    let mut right = whole.clone();
    right.merge(&LatencyHistogram::new());
    assert_eq!(right.count(), whole.count());
    assert_eq!(right.bucket_counts(), whole.bucket_counts());
    assert_eq!(right.max_ns(), whole.max_ns());
    assert!((right.mean_ms() - whole.mean_ms()).abs() < 1e-12);
}

#[test]
fn welford_merge_property_many_seeds_and_splits() {
    for seed in [3u64, 17, 1234, 0xDEAD] {
        let mut rng = Rng::new(seed);
        let xs: Vec<f64> = (0..400).map(|_| rng.normal_ms(5.0, 3.0) as f64).collect();
        let mut whole = Welford::default();
        for &x in &xs {
            whole.push(x);
        }
        for split in [0usize, 1, 100, 399, 400] {
            let (mut a, mut b) = (Welford::default(), Welford::default());
            for &x in &xs[..split] {
                a.push(x);
            }
            for &x in &xs[split..] {
                b.push(x);
            }
            a.merge(&b);
            assert_eq!(a.n(), whole.n());
            assert!((a.mean() - whole.mean()).abs() < 1e-9, "seed {seed} split {split}");
            assert!((a.std_dev() - whole.std_dev()).abs() < 1e-9, "seed {seed} split {split}");
        }
    }
}

/// Drive two independent `ServeMetrics` with seeded synthetic traffic,
/// merge, and check the counter books balance against a single-stream
/// control.
#[test]
fn serve_metrics_merge_balances_the_books() {
    let drive = |m: &mut ServeMetrics, seed: u64, events: usize| {
        let mut rng = Rng::new(seed);
        for _ in 0..events {
            match rng.below(8) {
                0 => m.predicts += 1,
                1 => m.feedbacks += 1,
                2 => m.queue_rejections += 1,
                3 => m.adaptations += 1,
                4 => m.finetune_cache_hits += 2,
                5 => m.finetune_cache_misses += 1,
                6 => {
                    m.batches += 1;
                    m.batched_rows += rng.below(32) as u64 + 1;
                    m.pump_ticks += 1;
                    m.batch_forward.record_ns(rng.range(10_000, 10_000_000) as u64);
                }
                _ => {
                    m.finetune.record_secs(rng.uniform(0.001, 0.05) as f64);
                    m.finetune_forward_ns += rng.below(1_000_000) as u64;
                    m.finetune_backward_ns += rng.below(2_000_000) as u64;
                    m.finetune_update_ns += rng.below(500_000) as u64;
                    m.finetune_cache_ns += rng.below(100_000) as u64;
                }
            }
        }
    };

    // control: one node that saw ALL the traffic (same seeds, same order
    // per stream — counters are order-insensitive sums)
    let mut whole = ServeMetrics::new();
    drive(&mut whole, 111, 300);
    drive(&mut whole, 222, 500);

    let mut a = ServeMetrics::new();
    drive(&mut a, 111, 300);
    let mut b = ServeMetrics::new();
    drive(&mut b, 222, 500);
    a.merge(&b);

    assert_eq!(a.predicts, whole.predicts);
    assert_eq!(a.feedbacks, whole.feedbacks);
    assert_eq!(a.queue_rejections, whole.queue_rejections);
    assert_eq!(a.adaptations, whole.adaptations);
    assert_eq!(a.finetune_cache_hits, whole.finetune_cache_hits);
    assert_eq!(a.finetune_cache_misses, whole.finetune_cache_misses);
    assert_eq!(a.batches, whole.batches);
    assert_eq!(a.batched_rows, whole.batched_rows);
    assert_eq!(a.pump_ticks, whole.pump_ticks);
    assert_eq!(a.finetune_forward_ns, whole.finetune_forward_ns);
    assert_eq!(a.finetune_backward_ns, whole.finetune_backward_ns);
    assert_eq!(a.finetune_update_ns, whole.finetune_update_ns);
    assert_eq!(a.finetune_cache_ns, whole.finetune_cache_ns);
    // histograms rode along
    assert_eq!(a.batch_forward.count(), whole.batch_forward.count());
    assert_eq!(a.batch_forward.bucket_counts(), whole.batch_forward.bucket_counts());
    assert_eq!(a.finetune.count(), whole.finetune.count());
    assert_eq!(a.finetune.max_ns(), whole.finetune.max_ns());
    // derived views agree exactly (same integer inputs)
    assert_eq!(a.rows_per_batch(), whole.rows_per_batch());
    assert_eq!(a.rows_per_pump(), whole.rows_per_pump());
    assert_eq!(a.finetune_cache_hit_rate(), whole.finetune_cache_hit_rate());
}
