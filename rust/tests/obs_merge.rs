//! Merge-law property tests for the fleet-telemetry primitives
//! (DESIGN.md §11): merging per-node `LatencyHistogram` / `Welford` /
//! `ServeMetrics` accumulators must reproduce the single-stream result —
//! counts and maxima bit-exactly, floating-point moments to within
//! rounding — and must be associative, because fleet aggregation happens
//! in whatever order snapshots arrive.

use skip2lora::obs::stages::{FlushStage, FlushStages};
use skip2lora::obs::trace::{EventKind, FlightRecorder, RecorderSummary, SUMMARY_TAIL};
use skip2lora::serve::metrics::{LatencyHistogram, ServeMetrics};
use skip2lora::util::rng::Rng;
use skip2lora::util::stats::Welford;

/// Latency-shaped samples spanning many histogram buckets: a log-uniform
/// body (1µs..16ms) plus occasional extreme outliers into the tail.
fn latency_samples(seed: u64, n: usize) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            if rng.below(50) == 0 {
                // rare outlier: 100ms..1s, exercises the max-bucket path
                rng.range(100_000_000, 1_000_000_000) as u64
            } else {
                // log-uniform over ~14 buckets
                let exp = rng.uniform(10.0, 24.0);
                2f64.powf(exp as f64) as u64
            }
        })
        .collect()
}

fn hist_of(samples: &[u64]) -> LatencyHistogram {
    let mut h = LatencyHistogram::new();
    for &ns in samples {
        h.record_ns(ns);
    }
    h
}

#[test]
fn histogram_merge_equals_single_stream() {
    for seed in [1u64, 42, 0xBEEF, 7_777_777] {
        let samples = latency_samples(seed, 500);
        let whole = hist_of(&samples);
        // several split points, including degenerate ones
        for split in [0usize, 1, 250, 499, 500] {
            let mut a = hist_of(&samples[..split]);
            let b = hist_of(&samples[split..]);
            a.merge(&b);
            // discrete state is bit-exact
            assert_eq!(a.count(), whole.count(), "seed {seed} split {split}");
            assert_eq!(a.max_ns(), whole.max_ns(), "seed {seed} split {split}");
            assert_eq!(a.bucket_counts(), whole.bucket_counts(), "seed {seed} split {split}");
            // every percentile is derived from buckets + max, so it must
            // agree exactly once those do
            for p in [50.0, 95.0, 99.0, 100.0] {
                assert_eq!(a.percentile_ms(p), whole.percentile_ms(p), "p{p}");
            }
            // moments agree to rounding
            assert!((a.mean_ms() - whole.mean_ms()).abs() < 1e-9, "seed {seed}");
            assert!((a.std_ms() - whole.std_ms()).abs() < 1e-9, "seed {seed}");
        }
    }
}

#[test]
fn histogram_merge_is_associative() {
    let samples = latency_samples(99, 600);
    let (s1, s2, s3) = (&samples[..200], &samples[200..350], &samples[350..]);
    // (a ⊕ b) ⊕ c
    let mut left = hist_of(s1);
    left.merge(&hist_of(s2));
    left.merge(&hist_of(s3));
    // a ⊕ (b ⊕ c)
    let mut bc = hist_of(s2);
    bc.merge(&hist_of(s3));
    let mut right = hist_of(s1);
    right.merge(&bc);
    assert_eq!(left.count(), right.count());
    assert_eq!(left.max_ns(), right.max_ns());
    assert_eq!(left.bucket_counts(), right.bucket_counts());
    assert!((left.mean_ms() - right.mean_ms()).abs() < 1e-9);
    assert!((left.std_ms() - right.std_ms()).abs() < 1e-9);
}

#[test]
fn histogram_merge_empty_is_identity() {
    let samples = latency_samples(5, 100);
    let whole = hist_of(&samples);
    // empty ⊕ x == x
    let mut left = LatencyHistogram::new();
    left.merge(&whole);
    assert_eq!(left.count(), whole.count());
    assert_eq!(left.bucket_counts(), whole.bucket_counts());
    assert_eq!(left.max_ns(), whole.max_ns());
    // x ⊕ empty == x
    let mut right = whole.clone();
    right.merge(&LatencyHistogram::new());
    assert_eq!(right.count(), whole.count());
    assert_eq!(right.bucket_counts(), whole.bucket_counts());
    assert_eq!(right.max_ns(), whole.max_ns());
    assert!((right.mean_ms() - whole.mean_ms()).abs() < 1e-12);
}

#[test]
fn welford_merge_property_many_seeds_and_splits() {
    for seed in [3u64, 17, 1234, 0xDEAD] {
        let mut rng = Rng::new(seed);
        let xs: Vec<f64> = (0..400).map(|_| rng.normal_ms(5.0, 3.0) as f64).collect();
        let mut whole = Welford::default();
        for &x in &xs {
            whole.push(x);
        }
        for split in [0usize, 1, 100, 399, 400] {
            let (mut a, mut b) = (Welford::default(), Welford::default());
            for &x in &xs[..split] {
                a.push(x);
            }
            for &x in &xs[split..] {
                b.push(x);
            }
            a.merge(&b);
            assert_eq!(a.n(), whole.n());
            assert!((a.mean() - whole.mean()).abs() < 1e-9, "seed {seed} split {split}");
            assert!((a.std_dev() - whole.std_dev()).abs() < 1e-9, "seed {seed} split {split}");
        }
    }
}

/// Drive two independent `ServeMetrics` with seeded synthetic traffic,
/// merge, and check the counter books balance against a single-stream
/// control.
#[test]
fn serve_metrics_merge_balances_the_books() {
    let drive = |m: &mut ServeMetrics, seed: u64, events: usize| {
        let mut rng = Rng::new(seed);
        for _ in 0..events {
            match rng.below(8) {
                0 => m.predicts += 1,
                1 => m.feedbacks += 1,
                2 => m.queue_rejections += 1,
                3 => m.adaptations += 1,
                4 => m.finetune_cache_hits += 2,
                5 => m.finetune_cache_misses += 1,
                6 => {
                    m.batches += 1;
                    m.batched_rows += rng.below(32) as u64 + 1;
                    m.pump_ticks += 1;
                    m.batch_forward.record_ns(rng.range(10_000, 10_000_000) as u64);
                }
                _ => {
                    m.finetune.record_secs(rng.uniform(0.001, 0.05) as f64);
                    m.finetune_forward_ns += rng.below(1_000_000) as u64;
                    m.finetune_backward_ns += rng.below(2_000_000) as u64;
                    m.finetune_update_ns += rng.below(500_000) as u64;
                    m.finetune_cache_ns += rng.below(100_000) as u64;
                }
            }
        }
    };

    // control: one node that saw ALL the traffic (same seeds, same order
    // per stream — counters are order-insensitive sums)
    let mut whole = ServeMetrics::new();
    drive(&mut whole, 111, 300);
    drive(&mut whole, 222, 500);

    let mut a = ServeMetrics::new();
    drive(&mut a, 111, 300);
    let mut b = ServeMetrics::new();
    drive(&mut b, 222, 500);
    a.merge(&b);

    assert_eq!(a.predicts, whole.predicts);
    assert_eq!(a.feedbacks, whole.feedbacks);
    assert_eq!(a.queue_rejections, whole.queue_rejections);
    assert_eq!(a.adaptations, whole.adaptations);
    assert_eq!(a.finetune_cache_hits, whole.finetune_cache_hits);
    assert_eq!(a.finetune_cache_misses, whole.finetune_cache_misses);
    assert_eq!(a.batches, whole.batches);
    assert_eq!(a.batched_rows, whole.batched_rows);
    assert_eq!(a.pump_ticks, whole.pump_ticks);
    assert_eq!(a.finetune_forward_ns, whole.finetune_forward_ns);
    assert_eq!(a.finetune_backward_ns, whole.finetune_backward_ns);
    assert_eq!(a.finetune_update_ns, whole.finetune_update_ns);
    assert_eq!(a.finetune_cache_ns, whole.finetune_cache_ns);
    // histograms rode along
    assert_eq!(a.batch_forward.count(), whole.batch_forward.count());
    assert_eq!(a.batch_forward.bucket_counts(), whole.batch_forward.bucket_counts());
    assert_eq!(a.finetune.count(), whole.finetune.count());
    assert_eq!(a.finetune.max_ns(), whole.finetune.max_ns());
    // derived views agree exactly (same integer inputs)
    assert_eq!(a.rows_per_batch(), whole.rows_per_batch());
    assert_eq!(a.rows_per_pump(), whole.rows_per_pump());
    assert_eq!(a.finetune_cache_hit_rate(), whole.finetune_cache_hit_rate());
}

// ---------------------------------------------------------------------
// lane-fold merge laws (DESIGN.md §13): `ObsSnapshot` for a multi-lane
// server folds per-lane `FlushStages` and `RecorderSummary` instances
// into one document, so both merges must be associative with the empty
// lane as identity — lanes aggregate in whatever order the fold visits.
// ---------------------------------------------------------------------

/// Seeded synthetic stage attribution, as if a lane had timed `flushes`
/// flushes.
fn stages_of(seed: u64, flushes: usize) -> FlushStages {
    let mut rng = Rng::new(seed);
    let mut st = FlushStages::new(true);
    for _ in 0..flushes {
        let mut total = 0u64;
        for stage in FlushStage::ALL {
            let ns = rng.range(1_000, 500_000) as u64;
            st.add_ns(stage, ns);
            total += ns;
        }
        // measured flush total: stage sum plus untimed slack
        st.finish_flush_ns(total + rng.below(10_000) as u64);
    }
    st
}

fn assert_stages_eq(a: &FlushStages, b: &FlushStages) {
    assert_eq!(a.flushes(), b.flushes());
    assert_eq!(a.total_ns(), b.total_ns());
    for stage in FlushStage::ALL {
        assert_eq!(a.stage_ns(stage), b.stage_ns(stage), "stage {}", stage.name());
    }
    assert_eq!(a.sum_stage_ns(), b.sum_stage_ns());
}

#[test]
fn flush_stages_lane_fold_is_associative_with_empty_identity() {
    let (a, b, c) = (stages_of(1, 5), stages_of(2, 3), stages_of(3, 8));
    // (a ⊕ b) ⊕ c
    let mut left = a.clone();
    left.merge(&b);
    left.merge(&c);
    // a ⊕ (b ⊕ c)
    let mut bc = b.clone();
    bc.merge(&c);
    let mut right = a.clone();
    right.merge(&bc);
    assert_stages_eq(&left, &right);
    // an idle lane is the identity on both sides
    let empty = FlushStages::new(true);
    let mut le = a.clone();
    le.merge(&empty);
    assert_stages_eq(&le, &a);
    let mut re = FlushStages::new(true);
    re.merge(&a);
    assert_stages_eq(&re, &a);
    // the fold reads as one lane that timed every flush
    assert_eq!(left.flushes(), 16);
    assert_eq!(
        left.total_ns(),
        a.total_ns() + b.total_ns() + c.total_ns(),
        "lane totals must sum exactly"
    );
}

/// A recorder that traced `n` flush cycles at distinct pump ticks,
/// offset so interleaved lanes produce a genuinely shuffled merge order.
fn lane_recorder(capacity: usize, n: usize, tick0: u64, tick_step: u64) -> FlightRecorder {
    let mut r = FlightRecorder::new(capacity, true);
    for i in 0..n {
        r.set_tick(tick0 + i as u64 * tick_step);
        r.record(EventKind::FlushStart { pending: 4 });
        r.record(EventKind::FanoutTenant { tenant: i as u64, rows: 2 });
        r.record(EventKind::FlushEnd { rows: 4, ns: 1_000 });
    }
    r
}

fn assert_summary_books(s: &RecorderSummary) {
    // counts carry the full kind taxonomy in wire order, and the tail is
    // a valid validator input: bounded, seqs strictly increasing,
    // tick-ordered (the deterministic merge clock)
    assert_eq!(s.counts.len(), 12);
    assert!(s.tail.len() <= SUMMARY_TAIL);
    for pair in s.tail.windows(2) {
        assert!(pair[1].seq > pair[0].seq, "seqs must stay strictly increasing");
        assert!(pair[1].tick >= pair[0].tick, "tail must be tick-ordered");
    }
    let total: u64 = s.counts.iter().map(|(_, n)| n).sum();
    assert_eq!(total, s.recorded, "per-kind counts must sum to recorded");
}

#[test]
fn recorder_summary_lane_merge_sums_books_and_interleaves_tails() {
    // three lanes with interleaved tick histories, all under SUMMARY_TAIL
    let lanes = [
        lane_recorder(64, 5, 0, 3),
        lane_recorder(64, 4, 1, 3),
        lane_recorder(64, 6, 2, 3),
    ];
    let mut acc = lanes[0].summary();
    for lane in &lanes[1..] {
        acc.merge(&lane.summary());
    }
    assert_eq!(acc.capacity, 192);
    assert_eq!(acc.recorded, (5 + 4 + 6) * 3);
    assert_eq!(acc.dropped, 0);
    assert_eq!(acc.tail.len(), 45);
    assert_summary_books(&acc);
    // per-kind counts sum by name across lanes
    for (k, (name, n)) in acc.counts.iter().enumerate() {
        let want: u64 = lanes.iter().map(|l| l.summary().counts[k].1).sum();
        assert_eq!(*n, want, "kind {name}");
    }
}

#[test]
fn recorder_summary_merge_is_associative_under_the_tail_cap() {
    let (a, b, c) = (
        lane_recorder(64, 6, 0, 5).summary(),
        lane_recorder(64, 5, 1, 5).summary(),
        lane_recorder(64, 7, 2, 5).summary(),
    );
    // 18 cycles * 3 events = 54 < SUMMARY_TAIL, so no truncation and the
    // merge must be exactly associative
    let mut left = a.clone();
    left.merge(&b);
    left.merge(&c);
    let mut bc = b.clone();
    bc.merge(&c);
    let mut right = a.clone();
    right.merge(&bc);
    assert_eq!(left.capacity, right.capacity);
    assert_eq!(left.recorded, right.recorded);
    assert_eq!(left.dropped, right.dropped);
    assert_eq!(left.counts, right.counts);
    assert_eq!(left.tail.len(), right.tail.len());
    for (le, re) in left.tail.iter().zip(right.tail.iter()) {
        assert_eq!((le.seq, le.tick, le.kind), (re.seq, re.tick, re.kind));
    }
    assert_summary_books(&left);
}

#[test]
fn recorder_summary_merge_truncates_to_newest_ticks_visibly() {
    // two long-history lanes: merged tail must keep the NEWEST ticks and
    // stay bounded, while the books still count everything ever recorded
    let a = lane_recorder(256, 30, 0, 2).summary();
    let b = lane_recorder(256, 30, 1, 2).summary();
    let mut acc = a.clone();
    acc.merge(&b);
    assert_eq!(acc.recorded, 180);
    assert_eq!(acc.tail.len(), SUMMARY_TAIL);
    assert_summary_books(&acc);
    // reference model: stable-sort the concatenated tails by tick (lane
    // order preserved on ties) and keep the newest SUMMARY_TAIL — the
    // merged tail must be exactly that suffix
    let mut reference: Vec<_> = a.tail.iter().chain(b.tail.iter()).copied().collect();
    reference.sort_by_key(|e| e.tick);
    let suffix = &reference[reference.len() - SUMMARY_TAIL..];
    for (got, want) in acc.tail.iter().zip(suffix) {
        assert_eq!((got.tick, got.kind), (want.tick, want.kind));
    }
}
