//! Durable tenant state, end to end: a `FleetServer` populated with N
//! tenants is persisted, DROPPED, and restored with bit-identical adapter
//! weights, per-tenant versions ≥ their persisted values, and `Predict`
//! results identical pre/post restore. Torn, overflowing, and tampered
//! checkpoint files are rejected with typed errors — never a panic.
//!
//! The consistent-cut guarantee is stress-proved with `testkit::stress`:
//! concurrent publishers (+ a remover simulating admin tenant deletion)
//! race observer threads that capture checkpoints mid-churn; every
//! captured (tenant, version) must be one that was ACTUALLY published,
//! and restoring a captured cut must preserve version monotonicity for
//! everything published afterwards. The `#[ignore]`-tagged long variant
//! runs in CI's `stress` job (`cargo test --release -- --ignored`).

use std::sync::Arc;

use skip2lora::data::Dataset;
use skip2lora::model::{Mlp, MlpConfig};
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::serve::persist::RegistryCheckpoint;
use skip2lora::serve::registry::AdapterRegistry;
use skip2lora::serve::{FleetServer, RejectReason, Request, Response, ServeConfig, TenantId};
use skip2lora::tensor::{ops::Backend, Mat};
use skip2lora::testkit::stress::{self, StressConfig};
use skip2lora::train::trainer::pretrain;
use skip2lora::util::rng::Rng;

fn clustered(seed: u64, n: usize, shift: f32) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Mat::zeros(n, 8);
    let mut labels = Vec::new();
    for i in 0..n {
        let c = i % 3;
        for j in 0..8 {
            let base = if j % 3 == c { 2.0 } else { 0.0 };
            *x.at_mut(i, j) = base + shift + 0.3 * rng.normal();
        }
        labels.push(c);
    }
    Dataset { x, labels, n_classes: 3 }
}

fn backbone() -> Arc<Mlp> {
    let cfg = MlpConfig { dims: vec![8, 12, 12, 3], rank: 2, batch_norm: true };
    Arc::new(pretrain(cfg, &clustered(0, 120, 0.0), 50, 0.05, 1, Backend::Blocked))
}

fn server_on(bb: &Arc<Mlp>) -> FleetServer {
    FleetServer::new(
        Arc::clone(bb),
        ServeConfig { batch_capacity: 16, ..Default::default() },
    )
}

/// Distinct, non-trivial skip adapters (trained-looking: W_B randomized).
fn trained_adapters(rng: &mut Rng) -> Vec<LoraAdapter> {
    [8usize, 12, 12]
        .iter()
        .map(|&n_in| {
            let mut ad = LoraAdapter::new(rng, n_in, 2, 3);
            for v in ad.wb.data.iter_mut() {
                *v = 0.2 * rng.normal();
            }
            ad
        })
        .collect()
}

/// One Predict round-trip: (prediction, adapter version served).
fn predict_one(server: &mut FleetServer, tenant: TenantId, x: &[f32]) -> (usize, u64) {
    match server.handle(tenant, Request::Predict(x.to_vec())) {
        Response::Queued { .. } => {}
        other => panic!("{other:?}"),
    }
    let done = server.pump_until_drained();
    assert_eq!(done.len(), 1);
    (done[0].prediction, done[0].adapter_version)
}

// ---------------------------------------------------------------------
// the acceptance scenario: persist, DROP, restore
// ---------------------------------------------------------------------

#[test]
fn persisted_fleet_survives_a_server_drop_bit_identically() {
    const N_TENANTS: u64 = 14;
    let dir = std::env::temp_dir().join("s2l_persistence_accept");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fleet.s2l");

    let bb = backbone();
    let mut server = server_on(&bb);
    let mut rng = Rng::new(42);

    // N tenants with distinct published adapters; some republished so the
    // version sequence has per-tenant gaps
    let mut persisted_version = vec![0u64; N_TENANTS as usize];
    for t in 0..N_TENANTS {
        for _round in 0..=(t % 3) {
            match server.handle(t, Request::SwapAdapters(trained_adapters(&mut rng))) {
                Response::Swapped { version } => persisted_version[t as usize] = version,
                other => panic!("{other:?}"),
            }
        }
    }

    // pre-drop ground truth: predictions + weights per tenant
    let probes: Vec<Vec<f32>> = (0..N_TENANTS)
        .map(|t| clustered(100 + t, 1, 0.5).x.row(0).to_vec())
        .collect();
    let pre: Vec<(usize, u64)> = (0..N_TENANTS)
        .map(|t| predict_one(&mut server, t, &probes[t as usize]))
        .collect();
    let pre_weights: Vec<Vec<Mat>> = (0..N_TENANTS)
        .map(|t| {
            let snap = server.registry.snapshot(t).unwrap();
            snap.adapters.iter().flat_map(|a| [a.wa.clone(), a.wb.clone()]).collect()
        })
        .collect();

    let report = server.persist_to(&path).unwrap();
    assert_eq!(report.tenants, N_TENANTS as usize);
    drop(server); // the crash

    // a brand-new server process on the same deployed backbone
    let mut revived = server_on(&bb);
    assert_eq!(revived.registry.tenant_count(), 0, "fresh server is empty");
    let report = revived.restore_from(&path).unwrap();
    assert_eq!(report.tenants, N_TENANTS as usize);
    assert_eq!(report.installed, N_TENANTS as usize);

    for t in 0..N_TENANTS {
        let ti = t as usize;
        // versions ≥ persisted (exact, on a fresh registry)
        assert!(
            revived.tenant_version(t) >= persisted_version[ti],
            "tenant {t}: version rolled back across restore"
        );
        // weights bit-identical
        let snap = revived.registry.snapshot(t).unwrap();
        let weights: Vec<Mat> = snap
            .adapters
            .iter()
            .flat_map(|a| [a.wa.clone(), a.wb.clone()])
            .collect();
        assert_eq!(weights, pre_weights[ti], "tenant {t}: weights differ after restore");
        // Predict identical pre/post restore, served at the same version
        let (prediction, version) = predict_one(&mut revived, t, &probes[ti]);
        assert_eq!((prediction, version), pre[ti], "tenant {t}: serving changed");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn restore_never_rolls_back_a_live_fleet() {
    let dir = std::env::temp_dir().join("s2l_persistence_monotone");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fleet.s2l");

    let bb = backbone();
    let mut server = server_on(&bb);
    let mut rng = Rng::new(7);
    server.handle(1, Request::SwapAdapters(trained_adapters(&mut rng)));
    server.persist_to(&path).unwrap();

    // the fleet moves on AFTER the checkpoint
    let newer = match server.handle(1, Request::SwapAdapters(trained_adapters(&mut rng))) {
        Response::Swapped { version } => version,
        other => panic!("{other:?}"),
    };
    let newer_weights = server.registry.snapshot(1).unwrap().adapters[0].wb.clone();

    // restoring the OLD checkpoint into the live server must be a no-op
    // for tenant 1 (monotonicity beats the stale checkpoint)...
    let report = server.restore_from(&path).unwrap();
    assert_eq!(report.installed, 0, "stale checkpoint must not reinstall");
    assert_eq!(server.tenant_version(1), newer);
    assert_eq!(server.registry.snapshot(1).unwrap().adapters[0].wb, newer_weights);

    // ...and publishes after a restore still move forward
    let next = match server.handle(1, Request::SwapAdapters(trained_adapters(&mut rng))) {
        Response::Swapped { version } => version,
        other => panic!("{other:?}"),
    };
    assert!(next > newer);
    std::fs::remove_file(&path).ok();
}

#[test]
fn post_crash_retraining_beats_a_stale_checkpoint() {
    // version numbers reset with the process: a pre-crash checkpoint can
    // claim BIGGER numbers than adapters a tenant just retrained after
    // the restart. If the operator restores late, the retrain must
    // survive — live training always beats checkpoint data.
    let dir = std::env::temp_dir().join("s2l_persistence_crash_domain");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fleet.s2l");

    let bb = backbone();
    let mut server = server_on(&bb);
    let mut rng = Rng::new(19);
    for _ in 0..5 {
        server.handle(1, Request::SwapAdapters(trained_adapters(&mut rng)));
    }
    server.persist_to(&path).unwrap();
    let pre_crash_version = server.tenant_version(1);
    drop(server); // the crash — the version counter dies with it

    // post-crash: the tenant reconnects and retrains BEFORE the operator
    // gets around to restoring the checkpoint
    let mut revived = server_on(&bb);
    let retrained = trained_adapters(&mut rng);
    let marker = retrained[0].wb.data[0];
    match revived.handle(1, Request::SwapAdapters(retrained)) {
        Response::Swapped { version } => {
            assert!(version < pre_crash_version, "fresh counter restarts low")
        }
        other => panic!("{other:?}"),
    }

    // the late restore must NOT clobber the freshly trained adapters,
    // even though the checkpoint's version number is bigger
    let report = revived.restore_from(&path).unwrap();
    assert_eq!(report.tenants, 1);
    assert_eq!(report.installed, 0, "stale checkpoint clobbered live work");
    let live = revived.registry.snapshot(1).unwrap();
    assert!(live.restored_from_micros.is_none(), "live publish lost its provenance");
    assert_eq!(live.adapters[0].wb.data[0], marker, "retrained weights lost");

    // and the restore still healed the version domain: the next publish
    // outranks every pre-crash version
    match revived.handle(1, Request::SwapAdapters(trained_adapters(&mut rng))) {
        Response::Swapped { version } => assert!(version > pre_crash_version),
        other => panic!("{other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn out_of_order_restores_keep_the_newest_checkpoint() {
    // two crashes, two checkpoints: A (pre-crash, HIGH versions) then B
    // (post-crash retrain, LOW versions but captured later). Whatever
    // order the operator restores them in, B's weights must end up live
    // — checkpoints are ordered by capture stamp, not raw version.
    let dir = std::env::temp_dir().join("s2l_persistence_ooo");
    std::fs::create_dir_all(&dir).unwrap();
    let (path_a, path_b) = (dir.join("a.s2l"), dir.join("b.s2l"));

    let bb = backbone();
    let mut s1 = server_on(&bb);
    let mut rng = Rng::new(23);
    for _ in 0..4 {
        s1.handle(3, Request::SwapAdapters(trained_adapters(&mut rng)));
    }
    s1.persist_to(&path_a).unwrap();
    drop(s1); // crash #1

    let mut s2 = server_on(&bb);
    let newest = trained_adapters(&mut rng);
    let marker = newest[0].wb.data[0];
    s2.handle(3, Request::SwapAdapters(newest));
    s2.persist_to(&path_b).unwrap();
    drop(s2); // crash #2

    // restore A then B: the later-captured B replaces A
    let mut s3 = server_on(&bb);
    s3.restore_from(&path_a).unwrap();
    s3.restore_from(&path_b).unwrap();
    let live = s3.registry.snapshot(3).unwrap();
    assert_eq!(live.adapters[0].wb.data[0], marker, "newest checkpoint lost");

    // restore B then A: the stale A must not resurrect
    let mut s4 = server_on(&bb);
    s4.restore_from(&path_b).unwrap();
    let report = s4.restore_from(&path_a).unwrap();
    assert_eq!(report.installed, 0, "stale checkpoint resurrected");
    let live = s4.registry.snapshot(3).unwrap();
    assert_eq!(live.adapters[0].wb.data[0], marker);
    std::fs::remove_file(&path_a).ok();
    std::fs::remove_file(&path_b).ok();
}

#[test]
fn incompatible_checkpoints_are_rejected_whole() {
    let dir = std::env::temp_dir().join("s2l_persistence_shape");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("wrong_shape.s2l");

    // a checkpoint from a DIFFERENT deployment (6-wide input model)
    let alien = AdapterRegistry::new();
    let mut rng = Rng::new(9);
    let ads: Vec<LoraAdapter> =
        [6usize, 12, 12].iter().map(|&n| LoraAdapter::new(&mut rng, n, 2, 3)).collect();
    alien.publish(5, ads);
    RegistryCheckpoint::capture(&alien).save(&path).unwrap();

    let bb = backbone();
    let mut server = server_on(&bb);
    let e = server.restore_from(&path).unwrap_err();
    assert!(e.to_string().contains("tenant 5"), "{e}");
    assert_eq!(server.registry.tenant_count(), 0, "rejected whole, nothing installed");

    // same through the request front-end
    match server.handle(0, Request::RestoreState(path.clone())) {
        Response::Rejected(RejectReason::PersistFailed(msg)) => {
            assert!(msg.contains("tenant 5"), "{msg}")
        }
        other => panic!("{other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn torn_files_on_disk_are_typed_errors_never_panics() {
    let dir = std::env::temp_dir().join("s2l_persistence_torn");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fleet.s2l");

    let bb = backbone();
    let mut server = server_on(&bb);
    let mut rng = Rng::new(11);
    for t in 0..5u64 {
        server.handle(t, Request::SwapAdapters(trained_adapters(&mut rng)));
    }
    server.persist_to(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // torn at every interesting boundary: header, manifest, mid-tensor
    for cut in [0, 3, 9, 40, bytes.len() / 2, bytes.len() - 1] {
        let torn = dir.join(format!("torn_{cut}.s2l"));
        std::fs::write(&torn, &bytes[..cut]).unwrap();
        let e = server.restore_from(&torn).unwrap_err();
        let msg = e.to_string();
        assert!(
            msg.contains("truncated") || msg.contains("manifest") || msg.contains("magic"),
            "cut {cut}: unexpected error {msg}"
        );
        std::fs::remove_file(&torn).ok();
    }

    // dimension-overflow header inside an otherwise plausible file
    let overflow = dir.join("overflow.s2l");
    let mut evil = Vec::new();
    evil.extend_from_slice(b"S2L1");
    evil.extend_from_slice(&1u32.to_le_bytes());
    evil.extend_from_slice(&1u32.to_le_bytes());
    evil.push(b'w');
    evil.extend_from_slice(&u32::MAX.to_le_bytes());
    evil.extend_from_slice(&u32::MAX.to_le_bytes());
    std::fs::write(&overflow, &evil).unwrap();
    let e = server.restore_from(&overflow).unwrap_err();
    assert!(e.to_string().contains("overflow"), "{e}");

    // the torn/overflowing files changed nothing
    assert_eq!(server.registry.tenant_count(), 5);
    std::fs::remove_file(&overflow).ok();
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------
// migration: export_tenant -> import_tenant across servers
// ---------------------------------------------------------------------

#[test]
fn tenant_migrates_between_nodes_with_identical_serving() {
    let bb = backbone();
    let mut node_a = server_on(&bb);
    let mut node_b = server_on(&bb);
    let mut rng = Rng::new(13);
    node_a.handle(77, Request::SwapAdapters(trained_adapters(&mut rng)));

    let probe = clustered(200, 1, 0.5).x.row(0).to_vec();
    let (pred_a, _) = predict_one(&mut node_a, 77, &probe);

    let payload = node_a.export_tenant(77).unwrap();
    let (tenant, version) = node_b.import_tenant(&payload).unwrap();
    assert_eq!(tenant, 77);
    assert!(version > 0, "import allocates a local version");

    let (pred_b, served_version) = predict_one(&mut node_b, 77, &probe);
    assert_eq!(pred_b, pred_a, "migrated tenant must serve identically");
    assert_eq!(served_version, version);

    // a payload from an incompatible deployment fails the rank checks
    let alien = AdapterRegistry::new();
    let ads: Vec<LoraAdapter> =
        [6usize, 12, 12].iter().map(|&n| LoraAdapter::new(&mut rng, n, 2, 3)).collect();
    alien.publish(3, ads);
    let bad = RegistryCheckpoint::capture_tenant(&alien, 3).unwrap().to_bytes();
    assert!(node_b.import_tenant(&bad).is_err());

    // a multi-tenant checkpoint is not a migration payload
    node_a.handle(78, Request::SwapAdapters(trained_adapters(&mut rng)));
    let two = RegistryCheckpoint::capture(&node_a.registry).to_bytes();
    let e = node_b.import_tenant(&two).unwrap_err();
    assert!(e.to_string().contains("exactly one"), "{e}");
}

// ---------------------------------------------------------------------
// persistence interleaved with TTL eviction
// ---------------------------------------------------------------------

#[test]
fn ttl_eviction_interleaved_with_checkpoints_loses_nothing() {
    let dir = std::env::temp_dir().join("s2l_persistence_ttl");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fleet.s2l");

    let bb = backbone();
    let mut server = FleetServer::new(
        Arc::clone(&bb),
        ServeConfig { batch_capacity: 8, idle_ttl_pumps: Some(6), ..Default::default() },
    );
    let mut rng = Rng::new(17);
    let mut versions = Vec::new();
    for t in 0..6u64 {
        match server.handle(t, Request::SwapAdapters(trained_adapters(&mut rng))) {
            Response::Swapped { version } => versions.push(version),
            other => panic!("{other:?}"),
        }
    }
    // idle long enough that the TTL sweep evicts ALL serve-side state,
    // interleaving checkpoints with the sweeps
    for i in 0..30 {
        server.pump();
        if i % 7 == 0 {
            server.persist_to(&path).unwrap();
        }
    }
    assert_eq!(server.tenant_count(), 0, "serve scratch must be swept");
    assert!(server.stats().evictions > 0);
    server.persist_to(&path).unwrap();

    // eviction dropped scratch, never registry state — the checkpoint
    // carries every published tenant, and a fresh server restores them
    let mut revived = server_on(&bb);
    let report = revived.restore_from(&path).unwrap();
    assert_eq!(report.tenants, 6);
    for (t, &v) in versions.iter().enumerate() {
        assert!(revived.tenant_version(t as u64) >= v, "tenant {t} lost by eviction");
    }
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------
// stress: checkpoints are consistent cuts under concurrent churn
// ---------------------------------------------------------------------

/// Concurrent publishers + a remover race observers capturing
/// checkpoints. Invariants on every captured cut:
///
/// * internal consistency: versions are 1..=next_version, tenants sorted
///   and unique, full serialize/parse roundtrip survives;
/// * every captured (tenant, version) was ACTUALLY allocated by some
///   publisher (no blended/torn versions — checked post-run against the
///   union of all publisher logs);
/// * restoring the final capture preserves monotonicity: publishes into
///   the restored registry outrank everything in the cut.
fn checkpoint_consistent_cut(workers: usize, ops: usize, seed: u64) {
    const TENANTS: usize = 10;
    let registry = AdapterRegistry::with_shards(8);
    let cfg = StressConfig { workers, ops, observers: 2, seed };

    let report = stress::run(
        &cfg,
        &registry,
        // workers: publish to random tenants, logging every allocated
        // (tenant, version); worker 0 doubles as the remover (admin
        // deletion racing the snapshot)
        |mut ctx, reg: &AdapterRegistry| {
            let mut log: Vec<(u64, u64)> = Vec::with_capacity(ctx.ops);
            for op in 0..ctx.ops {
                let t = ctx.rng.below(TENANTS) as u64;
                if ctx.index == 0 && op % 17 == 5 {
                    reg.remove(t);
                    continue;
                }
                let ads: Vec<LoraAdapter> = (0..3)
                    .map(|k| LoraAdapter::new(&mut ctx.rng, [8, 12, 12][k], 2, 3))
                    .collect();
                log.push((t, reg.publish(t, ads)));
            }
            log
        },
        // observers: capture checkpoints mid-churn, validate each cut's
        // internal consistency, and keep the last few for post-run checks
        |ctx, reg: &AdapterRegistry| {
            let mut kept: Vec<RegistryCheckpoint> = Vec::new();
            // capture-then-check so every observer keeps ≥ 1 cut even if
            // the workers finish before this thread gets scheduled
            loop {
                let ck = RegistryCheckpoint::capture(reg);
                for rec in &ck.tenants {
                    assert!(
                        rec.version() >= 1 && rec.version() <= ck.next_version,
                        "observer {}: version {} outside 1..={} (seed {seed:#x})",
                        ctx.index,
                        rec.version(),
                        ck.next_version
                    );
                }
                assert!(
                    ck.tenants.windows(2).all(|w| w[0].tenant() < w[1].tenant()),
                    "cut not sorted/unique (seed {seed:#x})"
                );
                // the full wire roundtrip must survive a mid-churn cut
                let back = RegistryCheckpoint::from_bytes(&ck.to_bytes())
                    .expect("mid-churn checkpoint must serialize+validate");
                assert_eq!(back.tenants.len(), ck.tenants.len());
                if kept.len() >= 4 {
                    kept.remove(0);
                }
                kept.push(ck);
                if !ctx.workers_live() {
                    break;
                }
            }
            kept
        },
    );

    // union of everything actually published
    let mut published: Vec<std::collections::HashSet<u64>> =
        vec![std::collections::HashSet::new(); TENANTS];
    for log in &report.workers {
        for &(t, v) in log {
            published[t as usize].insert(v);
        }
    }
    // every captured version exists in the publish log — a consistent
    // cut can never contain a version nobody was allocated
    let mut cuts = 0usize;
    for kept in &report.observers {
        for ck in kept {
            cuts += 1;
            for rec in &ck.tenants {
                assert!(
                    published[rec.tenant() as usize].contains(&rec.version()),
                    "cut holds tenant {} @ v{} which was never published (seed {seed:#x})",
                    rec.tenant(),
                    rec.version()
                );
            }
        }
    }
    assert!(cuts > 0, "observers never captured a checkpoint");

    // final capture restores into a fresh registry; publishes after the
    // restore outrank everything in the cut (monotonicity across restore)
    let final_ck = RegistryCheckpoint::capture(&registry);
    let fresh = AdapterRegistry::with_shards(2);
    final_ck.restore_into(&fresh);
    for rec in &final_ck.tenants {
        assert_eq!(fresh.version(rec.tenant()), rec.version());
    }
    let mut rng = Rng::new(seed ^ 0xF00D);
    let ads: Vec<LoraAdapter> =
        (0..3).map(|k| LoraAdapter::new(&mut rng, [8, 12, 12][k], 2, 3)).collect();
    let v = fresh.publish(0, ads);
    assert!(
        v > final_ck.next_version,
        "post-restore publish {v} must outrank the persisted counter {}",
        final_ck.next_version
    );
}

#[test]
fn checkpoints_are_consistent_cuts_under_churn() {
    checkpoint_consistent_cut(4, 120, 0x5EED_CAFE);
}

/// Long-running version. CI `stress` job only
/// (`cargo test --release -- --ignored`).
#[test]
#[ignore = "long-running stress; CI stress job runs it with --ignored"]
fn stress_checkpoint_consistent_cut_long() {
    for seed in 0..3u64 {
        checkpoint_consistent_cut(8, 1500, 0xD00D_0000 + seed);
    }
}
