//! Integration tests for the `serve` subsystem and the §4.2 cache-validity
//! contract it leans on:
//!
//! * the Skip-Cache freeze rule: cached activations are valid only while
//!   the backbone (FC weights AND BN statistics) is bit-frozen — any
//!   mutation requires invalidation;
//! * registry snapshot consistency under concurrent adapter publishes
//!   (mini-proptest over `testkit::stress` runs);
//! * shard routing: stable tenant → shard assignment, and per-shard
//!   snapshots partitioning the full registry;
//! * cross-tenant batching serves every tenant its own adapters with no
//!   interference.

use std::sync::Arc;

use skip2lora::cache::SkipCache;
use skip2lora::method::Method;
use skip2lora::model::{AdapterSet, Mlp, MlpConfig};
use skip2lora::nn::lora::LoraAdapter;
use skip2lora::serve::batcher::{BatchRequest, FrozenBackbone, MicroBatcher};
use skip2lora::serve::registry::AdapterRegistry;
use skip2lora::tensor::{ops::Backend, Mat};
use skip2lora::testkit::prop::{check, gen, PropConfig};
use skip2lora::testkit::stress::{self, StressConfig};
use skip2lora::train::FineTuner;
use skip2lora::util::rng::Rng;
use skip2lora::util::timer::PhaseTimer;

fn tiny_cfg() -> MlpConfig {
    MlpConfig { dims: vec![10, 8, 8, 3], rank: 2, batch_norm: true }
}

fn tiny_data(rng: &mut Rng, n: usize) -> skip2lora::data::Dataset {
    let x = gen::mat(rng, n, 10);
    let labels = gen::labels(rng, n, 3);
    skip2lora::data::Dataset { x, labels, n_classes: 3 }
}

// ---------------------------------------------------------------------
// §4.2 freeze rule
// ---------------------------------------------------------------------

/// Mutating BN running statistics after the cache is populated makes the
/// cached forward STALE: it keeps returning pre-mutation logits until the
/// cache is invalidated, after which the recomputed activations reflect
/// the new backbone state. This is exactly why every cache-compatible
/// method must freeze BN (paper §4.2 / DESIGN.md decision 5).
#[test]
fn bn_mutation_invalidates_cached_activations() {
    let mut rng = Rng::new(1);
    let model = Mlp::new(&mut rng, tiny_cfg());
    let data = tiny_data(&mut rng, 24);
    let mut tuner =
        FineTuner::with_fresh_adapters(model, Method::Skip2Lora, &mut rng, Backend::Blocked, 8);
    let mut cache = SkipCache::new(data.len());
    let mut timer = PhaseTimer::new();
    let idx: Vec<usize> = (0..8).collect();

    // populate + steady-state hit
    tuner.forward_cached(&data, &idx, &mut cache, &mut timer);
    let fresh = tuner.logits().clone();
    tuner.forward_cached(&data, &idx, &mut cache, &mut timer);
    assert_eq!(tuner.logits(), &fresh, "all-hit forward is bit-identical");

    // mutate frozen state: BN running stats drift (what train-mode BN
    // would do every batch); model_mut is copy-on-write but this tuner
    // holds the only reference, so the mutation is in place
    for v in tuner.model_mut().bns[0].running_mean.iter_mut() {
        *v += 0.5;
    }
    tuner.forward_cached(&data, &idx, &mut cache, &mut timer);
    assert_eq!(
        tuner.logits(),
        &fresh,
        "stale cache ignores the BN change — the §4.2 hazard"
    );

    // the required invalidation: clear, recompute, observe the new state
    cache.clear();
    tuner.forward_cached(&data, &idx, &mut cache, &mut timer);
    let recomputed = tuner.logits().clone();
    let max_delta = recomputed
        .data
        .iter()
        .zip(&fresh.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_delta > 1e-3,
        "recomputed logits must reflect the BN mutation (Δ={max_delta})"
    );
}

/// Same contract for FC weights: the other half of the frozen backbone.
#[test]
fn fc_mutation_invalidates_cached_activations() {
    let mut rng = Rng::new(2);
    let model = Mlp::new(&mut rng, tiny_cfg());
    let data = tiny_data(&mut rng, 16);
    let mut tuner =
        FineTuner::with_fresh_adapters(model, Method::Skip2Lora, &mut rng, Backend::Blocked, 8);
    let mut cache = SkipCache::new(data.len());
    let mut timer = PhaseTimer::new();
    let idx: Vec<usize> = (0..8).collect();

    tuner.forward_cached(&data, &idx, &mut cache, &mut timer);
    let fresh = tuner.logits().clone();

    {
        let fc = &mut tuner.model_mut().fcs[0];
        for v in fc.w.data.iter_mut() {
            *v *= 1.1;
        }
        fc.touch_weights(); // out-of-band mutation: invalidate Wᵀ caches
    }
    tuner.forward_cached(&data, &idx, &mut cache, &mut timer);
    assert_eq!(tuner.logits(), &fresh, "stale: FC change invisible through cache");

    cache.clear();
    tuner.forward_cached(&data, &idx, &mut cache, &mut timer);
    assert_ne!(tuner.logits(), &fresh, "post-clear forward sees the new weights");
    assert_eq!(cache.stats().misses, 8, "clear forces a full recompute");
}

/// Per-slot invalidation: replacing ONE buffer sample must only recompute
/// that slot — the others keep hitting (the serve-path reuse argument).
#[test]
fn slot_invalidation_is_surgical() {
    let mut rng = Rng::new(3);
    let model = Mlp::new(&mut rng, tiny_cfg());
    let mut data = tiny_data(&mut rng, 8);
    let mut tuner =
        FineTuner::with_fresh_adapters(model, Method::Skip2Lora, &mut rng, Backend::Blocked, 8);
    let mut cache = SkipCache::new(data.len());
    let mut timer = PhaseTimer::new();
    let idx: Vec<usize> = (0..8).collect();

    tuner.forward_cached(&data, &idx, &mut cache, &mut timer);
    assert_eq!(cache.stats().misses, 8);

    // slot 3 gets a new sample (ring-buffer overwrite in the server)
    for j in 0..10 {
        *data.x.at_mut(3, j) = rng.normal();
    }
    cache.invalidate(3);
    let before = cache.stats();
    tuner.forward_cached(&data, &idx, &mut cache, &mut timer);
    let after = cache.stats();
    assert_eq!(after.misses - before.misses, 1, "only the new sample recomputes");
    assert_eq!(after.hits - before.hits, 7);

    // and the recomputed entry matches a from-scratch forward of slot 3
    let mut oracle = SkipCache::new(data.len());
    tuner.forward_cached(&data, &idx, &mut oracle, &mut timer);
    assert_eq!(cache.peek(3).unwrap(), oracle.peek(3).unwrap());
}

// ---------------------------------------------------------------------
// registry consistency under concurrent publishes
// ---------------------------------------------------------------------

/// A published adapter set is immutable and replaced atomically: readers
/// racing a publisher must always observe an internally consistent set
/// (every weight tagged with the same publish round) and per-tenant
/// versions must be monotone — on every shard layout, including the
/// single-lock degenerate case. Each adapter set is tagged by filling
/// every W_B entry with the round number. The thread scaffolding is
/// `testkit::stress`: one publisher worker per tenant, two observers
/// hammering snapshots until the publishers finish.
#[test]
fn prop_registry_snapshots_consistent_under_concurrent_publishes() {
    check(
        "registry-snapshot-consistency",
        PropConfig { cases: 12, seed: 0xC0FFEE },
        |rng| {
            let shards = [1usize, 4, 16][gen::usize_in(rng, 0, 3)];
            let registry = AdapterRegistry::with_shards(shards);
            let tenants: u64 = gen::usize_in(rng, 1, 4) as u64;
            let rounds: usize = gen::usize_in(rng, 20, 60);
            let cfg = StressConfig {
                workers: tenants as usize,
                ops: rounds,
                observers: 2,
                seed: rng.next_u64(),
            };

            stress::run(
                &cfg,
                &registry,
                // publisher worker t: `rounds` tagged versions for tenant t
                |mut ctx, reg: &AdapterRegistry| {
                    let t = ctx.index as u64;
                    for round in 1..=ctx.ops {
                        let ads = (0..3)
                            .map(|_| {
                                let mut ad = LoraAdapter::new(&mut ctx.rng, 6, 2, 3);
                                ad.wb.fill(round as f32);
                                ad
                            })
                            .collect();
                        reg.publish(t, ads);
                    }
                },
                // observers: snapshots stay untorn and monotone throughout
                |ctx, reg: &AdapterRegistry| {
                    let mut last_version = vec![0u64; tenants as usize];
                    let mut last_tag = vec![0f32; tenants as usize];
                    while ctx.workers_live() {
                        for t in 0..tenants {
                            if let Some(snap) = reg.snapshot(t) {
                                // internal consistency: one tag everywhere
                                let tag = snap.adapters[0].wb.data[0];
                                for ad in &snap.adapters {
                                    for &v in &ad.wb.data {
                                        assert_eq!(
                                            v, tag,
                                            "torn snapshot on tenant {t} (observer {})",
                                            ctx.index
                                        );
                                    }
                                }
                                // monotone versions and tags per tenant
                                let ti = t as usize;
                                assert!(snap.version >= last_version[ti]);
                                assert!(tag >= last_tag[ti]);
                                last_version[ti] = snap.version;
                                last_tag[ti] = tag;
                            }
                        }
                    }
                },
            );

            // final state: every tenant at the last round's tag
            for t in 0..tenants {
                let snap = registry.snapshot(t).expect("published");
                if snap.adapters[0].wb.data[0] != rounds as f32 {
                    return Err(format!(
                        "tenant {t}: final tag {} != {rounds}",
                        snap.adapters[0].wb.data[0]
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// shard routing
// ---------------------------------------------------------------------

/// Routing is a pure function of the tenant id: the same tenant ALWAYS
/// lands on the same shard (within a registry and across registries with
/// the same shard count), and every shard index is in range.
#[test]
fn prop_same_tenant_always_routes_to_the_same_shard() {
    check(
        "shard-routing-stability",
        PropConfig { cases: 48, seed: 0x5AAD },
        |rng| {
            let shards = 1usize << gen::usize_in(rng, 0, 7); // 1..64
            let reg = AdapterRegistry::with_shards(shards);
            let twin = AdapterRegistry::with_shards(shards);
            for _ in 0..64 {
                let t = rng.next_u64();
                let s = reg.shard_of(t);
                if s >= reg.shard_count() {
                    return Err(format!("tenant {t}: shard {s} out of range"));
                }
                if s != reg.shard_of(t) {
                    return Err(format!("tenant {t}: unstable routing"));
                }
                if s != twin.shard_of(t) {
                    return Err(format!(
                        "tenant {t}: routing differs between equal-shard registries"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The per-shard views partition the registry: shard tenant sets are
/// disjoint, their union is exactly `tenants()`, every tenant sits on the
/// shard `shard_of` says, and a full-registry `snapshot_many` equals the
/// union of per-shard snapshots.
#[test]
fn prop_full_snapshot_equals_union_of_shard_snapshots() {
    check(
        "shard-snapshot-union",
        PropConfig { cases: 24, seed: 0x0DD_B175 },
        |rng| {
            let shards = 1usize << gen::usize_in(rng, 0, 6); // 1..32
            let reg = AdapterRegistry::with_shards(shards);
            let n = gen::usize_in(rng, 1, 64);
            for _ in 0..n {
                let t = rng.next_u64() % 997; // duplicates republish
                let ads = (0..3).map(|_| LoraAdapter::new(rng, 6, 2, 3)).collect();
                reg.publish(t, ads);
            }

            let mut union = Vec::new();
            for s in 0..reg.shard_count() {
                let ts = reg.shard_tenants(s);
                for &t in &ts {
                    if reg.shard_of(t) != s {
                        return Err(format!("tenant {t} on shard {s}, routed elsewhere"));
                    }
                }
                union.extend(ts);
            }
            let total = union.len();
            union.sort_unstable();
            union.dedup();
            if union.len() != total {
                return Err("shard tenant sets overlap".into());
            }
            if union != reg.tenants() {
                return Err(format!(
                    "union of shard views ({} tenants) != registry ({})",
                    union.len(),
                    reg.tenants().len()
                ));
            }

            // snapshot equivalence: the batched read path sees exactly the
            // per-shard state
            let many = reg.snapshot_many(union.iter().copied());
            if many.len() != union.len() {
                return Err("snapshot_many dropped a tenant".into());
            }
            for &t in &union {
                let direct = reg.snapshot(t).expect("published");
                let batched = &many[&t];
                if direct.version != batched.version {
                    return Err(format!(
                        "tenant {t}: snapshot version {} != snapshot_many {}",
                        direct.version, batched.version
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// cross-tenant batching
// ---------------------------------------------------------------------

/// One shared forward serves B tenants their OWN logits: equivalent to B
/// independent per-tenant model evaluations, with zero interference.
#[test]
fn batched_serving_matches_independent_per_tenant_models() {
    let mut rng = Rng::new(7);
    let cfg = tiny_cfg();
    let backbone = Arc::new(Mlp::new(&mut rng, cfg.clone()));
    let registry = Arc::new(AdapterRegistry::new());

    let n_tenants = 12u64;
    let mut tenant_adapters: Vec<Vec<LoraAdapter>> = Vec::new();
    for t in 0..n_tenants {
        let mut ads: Vec<LoraAdapter> = (0..3)
            .map(|k| LoraAdapter::new(&mut rng, cfg.dims[k], cfg.rank, 3))
            .collect();
        for ad in ads.iter_mut() {
            for v in ad.wb.data.iter_mut() {
                *v = 0.3 * rng.normal();
            }
        }
        tenant_adapters.push(ads.clone());
        registry.publish(t, ads);
    }

    let frozen =
        FrozenBackbone::new(Arc::clone(&backbone), Backend::Blocked, n_tenants as usize);
    let mut batcher = MicroBatcher::new(frozen, registry);
    let xs: Vec<Vec<f32>> = (0..n_tenants)
        .map(|_| (0..10).map(|_| rng.normal()).collect())
        .collect();
    for (t, x) in xs.iter().enumerate() {
        batcher.submit(BatchRequest { tenant: t as u64, id: t as u64, x: x.clone(), label: None });
    }
    let mut out = Vec::new();
    assert_eq!(batcher.flush(&mut out), n_tenants as usize);
    assert_eq!(batcher.batches, 1, "exactly one shared backbone forward");

    for (t, x) in xs.iter().enumerate() {
        // the "independent" model shares the SAME backbone Arc: adapters
        // are the only per-tenant state
        let solo = FineTuner::new(
            Arc::clone(&backbone),
            AdapterSet::skip_from(tenant_adapters[t].clone()),
            Method::SkipLora,
            Backend::Blocked,
            1,
        );
        let want = solo.predict_alloc(&Mat::from_vec(1, 10, x.clone()));
        for (a, b) in batcher.last_logits().row(out[t].row).iter().zip(want.row(0)) {
            assert!(
                (a - b).abs() < 1e-4,
                "tenant {t}: batched {a} vs independent {b}"
            );
        }
    }
}

/// Registry + batcher end to end: republishing ONE tenant's adapters
/// changes that tenant's logits and nobody else's.
#[test]
fn republish_changes_only_that_tenant() {
    let mut rng = Rng::new(8);
    let cfg = tiny_cfg();
    let backbone = Mlp::new(&mut rng, cfg.clone());
    let registry = Arc::new(AdapterRegistry::new());
    for t in 0..4u64 {
        let mut ads: Vec<LoraAdapter> = (0..3)
            .map(|k| LoraAdapter::new(&mut rng, cfg.dims[k], 2, 3))
            .collect();
        for ad in ads.iter_mut() {
            ad.wb.fill(0.1 * (t as f32 + 1.0));
        }
        registry.publish(t, ads);
    }
    let frozen = FrozenBackbone::new(backbone, Backend::Blocked, 4);
    let mut batcher = MicroBatcher::new(frozen, Arc::clone(&registry));
    let x: Vec<f32> = (0..10).map(|_| rng.normal()).collect();

    let serve_all = |batcher: &mut MicroBatcher| -> Vec<Vec<f32>> {
        for t in 0..4u64 {
            batcher.submit(BatchRequest { tenant: t, id: t, x: x.clone(), label: None });
        }
        let mut out = Vec::new();
        batcher.flush(&mut out);
        out.iter()
            .map(|r| batcher.logits_for(r).expect("single flush: rows are live").to_vec())
            .collect()
    };

    let before = serve_all(&mut batcher);
    // hot-swap tenant 2
    let mut new_ads: Vec<LoraAdapter> = (0..3)
        .map(|k| LoraAdapter::new(&mut rng, cfg.dims[k], 2, 3))
        .collect();
    for ad in new_ads.iter_mut() {
        ad.wb.fill(-0.7);
    }
    registry.publish(2, new_ads);
    let after = serve_all(&mut batcher);

    for t in 0..4usize {
        let changed = before[t]
            .iter()
            .zip(&after[t])
            .any(|(a, b)| (a - b).abs() > 1e-6);
        if t == 2 {
            assert!(changed, "tenant 2 must see its new adapters");
        } else {
            assert!(!changed, "tenant {t} must be unaffected by tenant 2's swap");
        }
    }
}
