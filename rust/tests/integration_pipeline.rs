//! End-to-end integration tests over the native engine: the §5.2 protocol
//! on the real (synthetic) Damage1 dataset at reduced epochs, plus the
//! paper's headline *shape* claims as assertions.

use skip2lora::data::fan::{damage, DamageKind};
use skip2lora::experiments::{accuracy, timing, DatasetId, ExpConfig};
use skip2lora::method::Method;
use skip2lora::model::AdapterSet;
use skip2lora::tensor::ops::Backend;
use skip2lora::train::FineTuner;

fn quick_cfg() -> ExpConfig {
    ExpConfig { trials: 1, epoch_scale: 0.12, seed: 7, ..Default::default() }
}

#[test]
fn drift_gap_exists_and_skip2_closes_it() {
    let cfg = quick_cfg();
    let ds = DatasetId::Damage1;
    let bench = ds.benchmark(cfg.seed);
    let backbone = accuracy::pretrain_backbone(ds, &bench, &cfg, 0);

    let probe = FineTuner::new(
        backbone.clone(),
        AdapterSet::none(),
        Method::FtAll,
        Backend::Blocked,
        20,
    );
    let before = probe.accuracy(&bench.test);

    let (after, out) =
        accuracy::finetune_and_test(ds, &bench, &backbone, Method::Skip2Lora, &cfg, 0);
    assert!(
        after > before + 0.15,
        "Skip2-LoRA must close a real drift gap: {before:.3} -> {after:.3}"
    );
    assert!(after > 0.85, "post-fine-tune accuracy too low: {after}");
    // the cache did its job
    let hr = out.cache_hits as f64 / (out.cache_hits + out.cache_misses) as f64;
    assert!(hr > 0.8, "hit rate {hr}");
    // paper §4.3: cache footprint below the input-data footprint
    assert!(out.cache_bytes < bench.finetune.len() * 256 * 4);
}

#[test]
fn skip2_accuracy_matches_skip_lora() {
    // Table 4's "Skip2-LoRA shows almost the same accuracy as Skip-LoRA":
    // the cache is exact, so given identical seeds the two methods must
    // produce near-identical test accuracy.
    let cfg = quick_cfg();
    let ds = DatasetId::Damage1;
    let bench = ds.benchmark(cfg.seed);
    let backbone = accuracy::pretrain_backbone(ds, &bench, &cfg, 0);
    let (a_skip, _) =
        accuracy::finetune_and_test(ds, &bench, &backbone, Method::SkipLora, &cfg, 0);
    let (a_skip2, _) =
        accuracy::finetune_and_test(ds, &bench, &backbone, Method::Skip2Lora, &cfg, 0);
    assert!(
        (a_skip - a_skip2).abs() < 0.02,
        "cache changed the training outcome: {a_skip} vs {a_skip2}"
    );
}

#[test]
fn timing_shape_matches_paper() {
    // §5.3 shape claims on this host (not absolute ms):
    //   backward: Skip-LoRA << LoRA-All (paper −82.5..88.3%)
    //   forward:  Skip2-LoRA << Skip-LoRA (paper −89.0..93.5%)
    //   train:    Skip2-LoRA ≈ 1/10 LoRA-All (paper −89.0..92.0%)
    let mut cfg = quick_cfg();
    cfg.epoch_scale = 0.25; // enough epochs for the cache to amortize
    let rows = timing::measure_methods(DatasetId::Damage1, &cfg);
    let get = |m: Method| rows.iter().find(|r| r.method == m).unwrap();
    let lora_all = get(Method::LoraAll);
    let skip = get(Method::SkipLora);
    let skip2 = get(Method::Skip2Lora);
    let ft_all = get(Method::FtAll);

    assert!(
        skip.backward_ms < 0.4 * lora_all.backward_ms,
        "Skip-LoRA bwd {:.4} vs LoRA-All {:.4}",
        skip.backward_ms,
        lora_all.backward_ms
    );
    assert!(
        skip2.forward_ms < 0.4 * skip.forward_ms,
        "Skip2 fwd {:.4} vs Skip-LoRA {:.4}",
        skip2.forward_ms,
        skip.forward_ms
    );
    assert!(
        skip2.train_ms < 0.35 * lora_all.train_ms,
        "Skip2 train {:.4} vs LoRA-All {:.4}",
        skip2.train_ms,
        lora_all.train_ms
    );
    // FT-All is the most expensive trainer
    assert!(ft_all.train_ms > skip2.train_ms);
    // prediction cost is method-independent (paper Tables 6/7 bottom row)
    let pmin = rows.iter().map(|r| r.predict_ms_per_sample).fold(f64::MAX, f64::min);
    let pmax = rows.iter().map(|r| r.predict_ms_per_sample).fold(0.0, f64::max);
    assert!(pmax < 4.0 * pmin, "predict spread too wide: {pmin} .. {pmax}");
}

#[test]
fn table2_shape_fc_dominates() {
    // Table 2's point: FC1/FC2 dominate both passes for FT-All-LoRA.
    let cfg = quick_cfg();
    let (fwd, bwd) = timing::table2(&cfg);
    let pct = |t: &skip2lora::report::Table, row_label: &str, col: usize| -> f64 {
        t.rows
            .iter()
            .find(|r| r[0] == row_label)
            .map(|r| r[col].parse::<f64>().unwrap())
            .unwrap()
    };
    // forward: FC1 is the largest fan row
    let fc1 = pct(&fwd, "FC1", 1);
    for label in ["LoRA1", "BN1", "Act1", "LoRA2", "BN2", "Act2", "LoRA3"] {
        assert!(fc1 > pct(&fwd, label, 1), "FC1 {fc1} vs {label}");
    }
    // backward: FC1 + FC2 together dominate (paper: 83.5% fan, 88.75% har)
    let heavy = pct(&bwd, "FC1", 1) + pct(&bwd, "FC2", 1);
    assert!(heavy > 50.0, "FC1+FC2 backward share {heavy}");
}

#[test]
fn damage2_is_harder_than_damage1() {
    // Table 3/4 shape: the chipped-blade task has lower accuracy.
    let cfg = quick_cfg();
    let d1 = damage(11, DamageKind::Holes);
    let d2 = damage(11, DamageKind::Chipped);
    let cfg2 = ExpConfig { seed: 11, ..cfg };
    let b1 = accuracy::pretrain_backbone(DatasetId::Damage1, &d1, &cfg2, 0);
    let b2 = accuracy::pretrain_backbone(DatasetId::Damage2, &d2, &cfg2, 0);
    let (a1, _) =
        accuracy::finetune_and_test(DatasetId::Damage1, &d1, &b1, Method::Skip2Lora, &cfg2, 0);
    let (a2, _) =
        accuracy::finetune_and_test(DatasetId::Damage2, &d2, &b2, Method::Skip2Lora, &cfg2, 0);
    assert!(a1 > a2, "Damage1 {a1} should beat Damage2 {a2}");
}
